//! The speculative decoding engine over any [`Backend`].
//!
//! Each [`Sequence`] owns a [`VerifyScratch`] arena and a reusable
//! [`Verdict`], so the per-block verification stage runs allocation-free in
//! steady state (the tentpole guarantee measured by `benches/verify_hot`).
//! The engine half runs on the always-built CPU reference backend in the
//! default configuration and on PJRT behind the `pjrt` feature.

use std::sync::Arc;
use std::time::Instant;

use anyhow::Result;

use super::{ActionPolicy, BlockStats, GenStats, StepFeatures};
use crate::dist::{DistStorage, NodeDist, SamplingConfig};
use crate::draft::{accepted_row_extent, Action, Drafted, DrafterKind, DraftScratch};
use crate::kvcache::{default_block_tokens, BlockPool, KvCache, KvStorage, PrefixCache};
use crate::runtime::{guard_finite, Backend, FaultOp, Role};
use crate::tokenizer;
use crate::tree::DraftTree;
use crate::util::Pcg64;
use crate::verify::{Verdict, Verifier, VerifyScratch};

/// One in-flight sequence: the per-request state of the serving loop
/// (token history, its own target/draft KV-cache lanes, selector feature
/// memory, and the warm verification arena). `Clone` snapshots a sequence
/// — used by tests that replay many blocks from one prefilled state.
#[derive(Clone)]
pub struct Sequence {
    /// Prompt + emitted tokens.
    pub tokens: Vec<u32>,
    /// Number of prompt tokens at the front of `tokens`.
    pub prompt_len: usize,
    /// This request's target-model KV-cache lane.
    pub target_kv: KvCache,
    /// This request's draft-model KV-cache lane.
    pub draft_kv: KvCache,
    /// Cache position of the current root (last committed) token.
    pub root_pos: usize,
    /// Set on EOS or when the context window is exhausted.
    pub finished: bool,
    /// Selector feature memory: target hidden at the previous root.
    pub prev_hidden_target: Vec<f32>,
    /// Selector feature memory: draft hidden at the previous root.
    pub prev_hidden_draft: Vec<f32>,
    /// Selector feature memory: target distribution at the previous root.
    pub prev_p: NodeDist,
    /// Selector feature memory: draft distribution at the previous root.
    pub prev_q: NodeDist,
    /// Reusable verification arena: warm after the first block, so every
    /// later verify call allocates nothing.
    pub scratch: VerifyScratch,
    /// Reusable drafting scratch (the branch-rollout handoff cache).
    pub draft_scratch: DraftScratch,
    /// Recycled verdict buffer (capacity persists across blocks).
    pub verdict: Verdict,
}

impl Sequence {
    /// Drop this sequence's KV lanes and drafting scratch, releasing their
    /// blocks back to the pool while keeping tokens, rng-independent
    /// feature memory and the verification arena. The hard half of
    /// preemption: the lane stays logically alive, but holds no cache
    /// memory — it can only resume after a
    /// [`SpecEngine::rebuild_prefill`] replay recommits rows
    /// `0..root_pos`, which reproduces the dropped rows bit-for-bit under
    /// the backend consistency contract.
    pub fn release_kv(&mut self) {
        self.target_kv = self.target_kv.new_like();
        self.draft_kv = self.draft_kv.new_like();
        self.draft_scratch = DraftScratch::default();
    }
}

/// In-flight chunked prefill: the resumable seam between
/// [`SpecEngine::start_chunked`] / [`SpecEngine::rebuild_prefill`] and the
/// finished [`Sequence`]. Each [`SpecEngine::prefill_step`] call runs one
/// bounded chunk through both models and commits its rows, so a serving
/// loop can interleave long prefills with decode ticks (and retire a lane
/// mid-prefill without losing determinism: the replay consumes no rng).
pub struct PrefillState {
    /// The context being prefilled: the truncated prompt, or — for a
    /// preemption rebuild — the committed tokens `0..root_pos`.
    tokens: Vec<u32>,
    /// Backend-facing copy of `tokens`.
    toks_i32: Vec<i32>,
    /// Rows already committed into the caches below.
    rows_done: usize,
    /// Rows this prefill must commit in total.
    rows_total: usize,
    /// Target lane under construction.
    target_kv: KvCache,
    /// Draft lane under construction.
    draft_kv: KvCache,
    /// Last chunk's target (logits, hidden) — the values `start()` would
    /// have produced, bitwise, once the final chunk lands.
    last_target: Option<(Vec<f32>, Vec<f32>)>,
    /// Last chunk's draft (logits, hidden).
    last_draft: Option<(Vec<f32>, Vec<f32>)>,
    /// Whether this replays an existing sequence's context (finish via
    /// [`SpecEngine::finish_rebuild`]) rather than a fresh prompt (finish
    /// via [`SpecEngine::finish_prefill`]).
    rebuild: bool,
}

impl PrefillState {
    /// Rows committed so far.
    pub fn rows_done(&self) -> usize {
        self.rows_done
    }
    /// Total rows this prefill will commit.
    pub fn rows_total(&self) -> usize {
        self.rows_total
    }
    /// Whether every row is committed and the state can be finished.
    pub fn is_done(&self) -> bool {
        self.rows_done >= self.rows_total
    }
    /// Whether this state replays an existing sequence's context.
    pub fn is_rebuild(&self) -> bool {
        self.rebuild
    }
}

/// One target/draft pair of shared block pools backing every paged lane a
/// [`SpecEngine`] creates. Lanes of one engine draw from (and retire into)
/// these pools, so resident memory — and, when the pools are capped, the
/// serving loop's admission budget — is accounted per *unique* block
/// across all in-flight sequences. `Clone` shares the pools (the fields
/// are [`Arc`]s), which is how the server keeps one pool pair — and the
/// radix prefix cache indexing it — alive across per-request engines.
#[derive(Clone)]
pub struct KvPools {
    /// Pool sized for the target model's dimensions.
    pub target: Arc<BlockPool>,
    /// Pool sized for the draft model's dimensions.
    pub draft: Arc<BlockPool>,
}

/// Which KV representation a [`SpecEngine`] gives its sequences.
enum KvContext {
    Contiguous,
    Paged(KvPools),
}

/// The speculative decoding engine for one family.
pub struct SpecEngine<'a> {
    /// The execution backend (CPU reference or PJRT).
    pub engine: &'a dyn Backend,
    /// Sampling configuration shared by target and draft.
    pub sampling: SamplingConfig,
    /// KV storage for sequences created by [`SpecEngine::start`].
    kv: KvContext,
    /// Drafting policy [`SpecEngine::step`] dispatches through.
    drafter: DrafterKind,
}

impl<'a> SpecEngine<'a> {
    /// Wrap a backend with a sampling configuration. KV storage follows
    /// [`KvStorage::global`] (env knob `SPECDELAY_PAGED_KV`); paged
    /// engines get fresh uncapped pools — use
    /// [`SpecEngine::with_paged_kv`] to cap them.
    pub fn new(engine: &'a dyn Backend, sampling: SamplingConfig) -> Self {
        SpecEngine {
            engine,
            sampling,
            kv: KvContext::Contiguous,
            drafter: DrafterKind::Delayed,
        }
        .with_kv_storage(KvStorage::global())
    }

    /// Select the drafting policy (default [`DrafterKind::Delayed`]).
    /// Every kind is lossless; [`SpecEngine::step`] shapes actions through
    /// the selected drafter's geometry.
    pub fn with_drafter(mut self, kind: DrafterKind) -> Self {
        self.set_drafter(kind);
        self
    }

    /// In-place [`SpecEngine::with_drafter`] (the serving loop re-applies
    /// the drafter across its engine-rebuilding builders this way).
    pub fn set_drafter(&mut self, kind: DrafterKind) {
        self.drafter = kind;
    }

    /// The active drafting policy.
    pub fn drafter(&self) -> DrafterKind {
        self.drafter
    }

    /// Select the KV representation explicitly (tests and benches cover
    /// both sides of the env knob in one process this way). Paged storage
    /// gets fresh uncapped pools with [`default_block_tokens`].
    pub fn with_kv_storage(self, storage: KvStorage) -> Self {
        match storage {
            KvStorage::Contiguous => SpecEngine { kv: KvContext::Contiguous, ..self },
            KvStorage::Paged => {
                let bt = default_block_tokens();
                self.with_paged_kv(bt, None)
            }
        }
    }

    /// Force paged KV storage with explicit block size and an optional
    /// per-pool block budget (both the target and the draft pool get
    /// `max_blocks`). Exhausting a capped pool panics on the write path,
    /// so callers gating admission (the batched
    /// [`ServeLoop`](super::ServeLoop)) must reserve worst-case blocks per
    /// lane before admitting it.
    pub fn with_paged_kv(mut self, block_tokens: usize, max_blocks: Option<usize>) -> Self {
        let meta = self.engine.meta();
        self.kv = KvContext::Paged(KvPools {
            target: BlockPool::new(meta.target, block_tokens, max_blocks),
            draft: BlockPool::new(meta.draft, block_tokens, max_blocks),
        });
        self
    }

    /// Adopt an *existing* pool pair instead of creating fresh ones: lanes
    /// of this engine share blocks (and a [`PrefixCache`] indexing them)
    /// with every other engine built over the same pools — the
    /// cross-request seam the TCP server uses to keep prefix KV alive
    /// between per-request engines.
    pub fn with_kv_pools(mut self, pools: KvPools) -> Self {
        self.kv = KvContext::Paged(pools);
        self
    }

    /// The shared block pools, when this engine uses paged storage.
    pub fn kv_pools(&self) -> Option<&KvPools> {
        match &self.kv {
            KvContext::Paged(p) => Some(p),
            KvContext::Contiguous => None,
        }
    }

    /// A fresh empty KV lane in this engine's storage.
    fn new_cache(&self, role: Role) -> KvCache {
        match &self.kv {
            KvContext::Contiguous => KvCache::new(self.engine.dims(role)),
            KvContext::Paged(pools) => KvCache::paged(match role {
                Role::Target => &pools.target,
                Role::Draft => &pools.draft,
            }),
        }
    }

    /// Prefill both models on the prompt.
    pub fn start(&self, prompt: &str) -> Result<Sequence> {
        let mut toks = tokenizer::encode(prompt);
        let s_pre = self.engine.meta().s_pre;
        if toks.is_empty() {
            toks.push(tokenizer::BOS);
        }
        toks.truncate(s_pre);
        let toks_i32: Vec<i32> = toks.iter().map(|&t| t as i32).collect();
        let len = toks.len();

        let t_out = self.engine.prefill(Role::Target, &toks_i32, len)?;
        guard_finite(FaultOp::Prefill, "target prefill logits", &t_out.logits)?;
        let d_out = self.engine.prefill(Role::Draft, &toks_i32, len)?;
        guard_finite(FaultOp::Prefill, "draft prefill logits", &d_out.logits)?;

        let mut target_kv = self.new_cache(Role::Target);
        let mut draft_kv = self.new_cache(Role::Draft);
        target_kv.commit_prefill(&t_out.k_rows, &t_out.v_rows, s_pre, len);
        draft_kv.commit_prefill(&d_out.k_rows, &d_out.v_rows, s_pre, len);

        let storage = DistStorage::global();
        let p0 = NodeDist::from_logits(&t_out.logits, self.sampling, storage);
        let q0 = NodeDist::from_logits(&d_out.logits, self.sampling, storage);
        let mut scratch = VerifyScratch::default();
        scratch.reserve(self.engine.meta().target.vocab, 32, 8);
        let mut verdict = Verdict::default();
        verdict.accepted.reserve(32);
        Ok(Sequence {
            tokens: toks,
            prompt_len: len,
            target_kv,
            draft_kv,
            root_pos: len - 1,
            finished: false,
            prev_hidden_target: t_out.hidden,
            prev_hidden_draft: d_out.hidden.clone(),
            prev_p: p0,
            prev_q: q0,
            scratch,
            draft_scratch: DraftScratch::default(),
            verdict,
        })
    }

    /// Begin a *chunked* prefill of `prompt`: tokenize and truncate exactly
    /// like [`SpecEngine::start`], but run no model work yet. Drive the
    /// returned state with [`SpecEngine::prefill_step`] and turn it into a
    /// [`Sequence`] with [`SpecEngine::finish_prefill`]; the result is
    /// bit-identical to `start()` for every chunk schedule (pinned by
    /// `chunked_prefill_matches_one_shot` and the scheduler equality grid
    /// in `tests/serve_sched.rs`).
    pub fn start_chunked(&self, prompt: &str) -> PrefillState {
        let mut toks = tokenizer::encode(prompt);
        let s_pre = self.engine.meta().s_pre;
        if toks.is_empty() {
            toks.push(tokenizer::BOS);
        }
        toks.truncate(s_pre);
        let toks_i32: Vec<i32> = toks.iter().map(|&t| t as i32).collect();
        let rows_total = toks.len();
        PrefillState {
            tokens: toks,
            toks_i32,
            rows_done: 0,
            rows_total,
            target_kv: self.new_cache(Role::Target),
            draft_kv: self.new_cache(Role::Draft),
            last_target: None,
            last_draft: None,
            rebuild: false,
        }
    }

    /// Begin a chunked prefill *warmed* by the radix prefix cache: like
    /// [`SpecEngine::start_chunked`], but the longest cached block run for
    /// the prompt is adopted into the fresh lanes (refcount bumps, no row
    /// copies) and `rows_done` starts at the matched row count, so
    /// [`SpecEngine::prefill_step`] begins at the first token past the
    /// cached prefix. Only `tokens[..len-1]` is probed, guaranteeing at
    /// least one fresh row — the final chunk's logits/hidden that
    /// [`SpecEngine::finish_prefill`] needs. Cached rows are bit-identical
    /// to the rows a cold prefill would commit (the backend consistency
    /// contract), so the finished [`Sequence`] — and every token it emits —
    /// matches the cold-cache run exactly.
    pub fn start_chunked_cached(&self, prompt: &str, cache: &mut PrefixCache) -> PrefillState {
        let mut st = self.start_chunked(prompt);
        let probe_len = st.tokens.len() - 1;
        let matched = cache.match_into(&st.tokens[..probe_len], &mut st.target_kv, &mut st.draft_kv);
        st.rows_done = matched;
        st
    }

    /// Begin replaying a hard-preempted sequence's context (after
    /// [`Sequence::release_kv`]): fresh lanes that, once every chunk has
    /// run, hold rows `0..root_pos` of both caches — bitwise the rows the
    /// sequence held before its memory was released, because a prefill
    /// row, a decode step, and a tree-pass node agree bit-for-bit given
    /// the same context (the backend consistency contract; the draft half
    /// is additionally pinned by `draft_cache_rows_match_from_scratch_prefill`).
    /// Rows at and past `root_pos` are recomputed by the next block itself.
    /// Finish with [`SpecEngine::finish_rebuild`].
    pub fn rebuild_prefill(&self, seq: &Sequence) -> PrefillState {
        let rows = seq.root_pos;
        let tokens: Vec<u32> = seq.tokens[..rows].to_vec();
        let toks_i32: Vec<i32> = tokens.iter().map(|&t| t as i32).collect();
        PrefillState {
            tokens,
            toks_i32,
            rows_done: 0,
            rows_total: rows,
            target_kv: self.new_cache(Role::Target),
            draft_kv: self.new_cache(Role::Draft),
            last_target: None,
            last_draft: None,
            rebuild: true,
        }
    }

    /// Run one prefill chunk of at most `chunk` rows through both models
    /// and commit the rows. Returns `Ok(true)` when the state is complete.
    /// On error nothing is committed and `rows_done` is unchanged, so the
    /// caller retries the same chunk (both dispatches are re-issued — the
    /// chunk commits only when target *and* draft pass the corruption
    /// guards, mirroring [`SpecEngine::start`]).
    pub fn prefill_step(&self, st: &mut PrefillState, chunk: usize) -> Result<bool> {
        if st.is_done() {
            return Ok(true);
        }
        let take = chunk.max(1).min(st.rows_total - st.rows_done);
        let start = st.rows_done;
        let t_out =
            self.engine.prefill_chunk(Role::Target, st.target_kv.view(), &st.toks_i32, start, take)?;
        guard_finite(FaultOp::Prefill, "target prefill logits", &t_out.logits)?;
        let d_out =
            self.engine.prefill_chunk(Role::Draft, st.draft_kv.view(), &st.toks_i32, start, take)?;
        guard_finite(FaultOp::Prefill, "draft prefill logits", &d_out.logits)?;
        st.target_kv.commit_chunk(&t_out.k_rows, &t_out.v_rows, take, start, take);
        st.draft_kv.commit_chunk(&d_out.k_rows, &d_out.v_rows, take, start, take);
        st.last_target = Some((t_out.logits, t_out.hidden));
        st.last_draft = Some((d_out.logits, d_out.hidden));
        st.rows_done += take;
        Ok(st.is_done())
    }

    /// Turn a completed fresh-prompt prefill into a [`Sequence`] —
    /// constructed exactly as [`SpecEngine::start`] would have, from the
    /// final chunk's logits/hidden (bitwise equal to the one-shot
    /// prefill's last row).
    pub fn finish_prefill(&self, st: PrefillState) -> Result<Sequence> {
        anyhow::ensure!(!st.rebuild, "finish_prefill on a rebuild state");
        anyhow::ensure!(st.is_done(), "prefill incomplete: {}/{}", st.rows_done, st.rows_total);
        let (t_logits, t_hidden) = st.last_target.expect("fresh prefill has >= 1 row");
        let (d_logits, d_hidden) = st.last_draft.expect("fresh prefill has >= 1 row");
        let storage = DistStorage::global();
        let p0 = NodeDist::from_logits(&t_logits, self.sampling, storage);
        let q0 = NodeDist::from_logits(&d_logits, self.sampling, storage);
        let mut scratch = VerifyScratch::default();
        scratch.reserve(self.engine.meta().target.vocab, 32, 8);
        let mut verdict = Verdict::default();
        verdict.accepted.reserve(32);
        let len = st.rows_total;
        Ok(Sequence {
            tokens: st.tokens,
            prompt_len: len,
            target_kv: st.target_kv,
            draft_kv: st.draft_kv,
            root_pos: len - 1,
            finished: false,
            prev_hidden_target: t_hidden,
            prev_hidden_draft: d_hidden,
            prev_p: p0,
            prev_q: q0,
            scratch,
            draft_scratch: DraftScratch::default(),
            verdict,
        })
    }

    /// Install a completed rebuild's caches into the preempted sequence.
    /// Everything else — tokens, rng position, feature memory — was never
    /// touched, so the resumed stream is bit-identical to an unpreempted
    /// run.
    pub fn finish_rebuild(&self, st: PrefillState, seq: &mut Sequence) -> Result<()> {
        anyhow::ensure!(st.rebuild, "finish_rebuild on a fresh-prompt state");
        anyhow::ensure!(st.is_done(), "rebuild incomplete: {}/{}", st.rows_done, st.rows_total);
        seq.target_kv = st.target_kv;
        seq.draft_kv = st.draft_kv;
        seq.draft_scratch = DraftScratch::default();
        Ok(())
    }

    /// Remaining position headroom for one block at the given action.
    fn fits(&self, seq: &Sequence, a: Action) -> bool {
        let depth = a.l1 + a.l2 + 2;
        seq.root_pos + depth < self.engine.meta().target.max_seq
    }

    /// One speculation block through the engine's own drafter
    /// ([`SpecEngine::with_drafter`]). Returns stats; marks `seq.finished`
    /// on EOS or length cap.
    pub fn step(
        &self,
        seq: &mut Sequence,
        verifier: &dyn Verifier,
        action: Action,
        rng: &mut Pcg64,
    ) -> Result<BlockStats> {
        self.step_drafted(seq, verifier, action, self.drafter, rng)
    }

    /// One speculation block with an explicit drafter — the per-block seam
    /// the serving-time selector drives, where each block may pick a
    /// different (verifier × drafter × action) arm. `step` delegates here
    /// with the engine-level drafter.
    pub fn step_drafted(
        &self,
        seq: &mut Sequence,
        verifier: &dyn Verifier,
        action: Action,
        drafter: DrafterKind,
        rng: &mut Pcg64,
    ) -> Result<BlockStats> {
        let meta = self.engine.meta();
        let dr = drafter.drafter();
        let mut a = dr.shape(action, &meta);
        if a.l1 == 0 && (a.k <= 1 || a.l2 == 0) {
            // always draft at least one token so the root's draft KV row
            // gets computed (see draft::draft_delayed)
            a = Action::new(1, 1, 0);
        }
        // shrink to fit the context window
        while !self.fits(seq, a) && a.l1 + a.l2 > 1 {
            if a.l2 > 1 {
                a.l2 -= 1;
            } else if a.l1 > 1 {
                a.l1 -= 1;
            } else {
                break;
            }
        }
        if !self.fits(seq, a) {
            seq.finished = true;
            return Ok(BlockStats::default());
        }

        let root_token = *seq.tokens.last().unwrap();

        // --- draft ---
        let t0 = Instant::now();
        let mut drafted = dr.draft(
            self.engine,
            &seq.draft_kv,
            root_token,
            seq.root_pos,
            a,
            self.sampling,
            &mut seq.draft_scratch,
            rng,
        )?;
        let draft_secs = t0.elapsed().as_secs_f64();
        let mut tree = std::mem::replace(&mut drafted.tree, DraftTree::new(0));

        // --- target tree pass ---
        let t1 = Instant::now();
        let n_bucket = meta.tree_bucket(tree.len())?;
        let (toks, pos) = tree.tokens_positions(n_bucket, seq.root_pos, tokenizer::PAD);
        let bias = tree.attention_bias(n_bucket);
        let out = self.engine.tree_verify(
            n_bucket,
            seq.target_kv.view(),
            &toks,
            &pos,
            &bias,
            seq.root_pos,
        )?;
        guard_finite(FaultOp::TreeVerify, "tree-pass logits", &out.logits)?;
        let v = meta.target.vocab;
        let storage = DistStorage::global();
        for i in 0..tree.len() {
            tree.set_p(
                i,
                NodeDist::from_logits(&out.logits[i * v..(i + 1) * v], self.sampling, storage),
            );
        }
        let tree_secs = t1.elapsed().as_secs_f64();

        // --- verification (allocation-free: sequence-owned arena) ---
        let t2 = Instant::now();
        let mut verdict = std::mem::take(&mut seq.verdict);
        verifier.verify_into(&tree, rng, &mut seq.scratch, &mut verdict);
        let verify_secs = t2.elapsed().as_secs_f64();

        // --- commit ---
        self.commit(seq, &tree, &drafted, &out, &verdict.accepted)?;
        let mut emitted: Vec<u32> =
            verdict.accepted.iter().map(|&n| tree.nodes[n].token).collect();
        emitted.push(verdict.correction);

        // feature memory: deepest accepted node predicts the new root
        let deepest = verdict.accepted.last().copied().unwrap_or(0);
        let accepted_len = verdict.tau();
        seq.verdict = verdict; // recycle the buffer for the next block
        let d_t = meta.target.d_model;
        seq.prev_hidden_target = out.hidden[deepest * d_t..(deepest + 1) * d_t].to_vec();
        if let Some(h) = draft_hidden_for(&tree, &drafted, deepest, meta.draft.d_model) {
            seq.prev_hidden_draft = h;
        }
        seq.prev_p = tree.nodes[deepest].p.clone().unwrap();
        if let Some(q) = tree.nodes[deepest].q.clone() {
            seq.prev_q = q;
        }

        for &t in &emitted {
            seq.tokens.push(t);
            if tokenizer::is_terminal(t) {
                seq.finished = true;
            }
        }
        seq.root_pos += emitted.len();
        if seq.root_pos + 3 >= meta.target.max_seq {
            seq.finished = true;
        }

        Ok(BlockStats {
            accepted: accepted_len,
            emitted: emitted.len(),
            draft_secs,
            tree_secs,
            verify_secs,
            tree_nodes: tree.len(),
        })
    }

    fn commit(
        &self,
        seq: &mut Sequence,
        tree: &DraftTree,
        drafted: &Drafted,
        out: &crate::runtime::TreeOut,
        accepted: &[usize],
    ) -> Result<()> {
        // target rows: root + accepted chain
        seq.target_kv
            .commit_tree_row(&out.k_rows, &out.v_rows, out.n, 0, seq.root_pos);
        for &n in accepted {
            let posn = seq.root_pos + tree.nodes[n].depth;
            seq.target_kv
                .commit_tree_row(&out.k_rows, &out.v_rows, out.n, n, posn);
        }

        // draft rows per rollout provenance
        let (trunk_ext, branch_ext) = accepted_row_extent(tree, accepted);
        if let Some(tr) = &drafted.trunk {
            let last = trunk_ext.unwrap_or(0).min(tr.l.saturating_sub(1));
            seq.draft_kv.commit_rollout_rows(
                &tr.k_rows, &tr.v_rows, 1, tr.l, 0, last, seq.root_pos,
            );
        }
        if let Some(br) = &drafted.branch {
            // commit the accepted branch's rows; if no branch node was
            // accepted, still commit step 0 of branch 0 (the branch-start /
            // root row lives there). Branch rows are based at the rollout's
            // start position: root_pos + l1 for delayed trees, root_pos
            // itself when the branches started at the root.
            let (b, s) = branch_ext.unwrap_or((0, 0));
            let last = s.min(br.l.saturating_sub(1));
            seq.draft_kv.commit_rollout_rows(
                &br.k_rows,
                &br.v_rows,
                br.k,
                br.l,
                b,
                last,
                seq.root_pos + drafted.branch_start,
            );
        }

        // Rollouts only carry rows for *visited* nodes, so a chain accepted
        // to the full drafted depth ends on a token whose draft row was
        // never computed (a fully accepted single-path trunk, or a branch
        // accepted to its compiled bucket's end). Back-fill it with one
        // draft decode — every later draft forward of this sequence
        // attends that row, so leaving it stale would silently corrupt all
        // subsequent draft distributions. The context rows it needs are
        // exactly the commits above. Asserted bitwise against from-scratch
        // prefills in tests/e2e_serve.rs.
        if let Some(&deepest) = accepted.last() {
            if draft_row_missing(tree, drafted, deepest) {
                let pos = seq.root_pos + tree.nodes[deepest].depth;
                let d = self.engine.decode(
                    Role::Draft,
                    seq.draft_kv.view(),
                    tree.nodes[deepest].token,
                    pos,
                )?;
                // the logits are unused here, but non-finite logits mean
                // the forward pass (and so the KV rows) cannot be trusted
                guard_finite(FaultOp::Decode, "backfill decode logits", &d.logits)?;
                seq.draft_kv.commit_row(&d.k_row, &d.v_row, pos);
            }
        }
        Ok(())
    }

    /// Pick the next block's action: consults the policy, running the extra
    /// root draft-decode feature pass only when the policy needs it. Shared
    /// by [`SpecEngine::generate`] and the batched
    /// [`super::ServeLoop`] so both drive identical per-block decisions.
    pub fn choose_action(&self, seq: &mut Sequence, policy: &dyn ActionPolicy) -> Result<Action> {
        if policy.needs_features() {
            let f = self.root_features(seq)?;
            Ok(policy.choose(&f.as_features(seq, self.sampling)))
        } else {
            Ok(policy.choose(&StepFeatures {
                hidden_p_prev: &seq.prev_hidden_target,
                hidden_q_prev: &seq.prev_hidden_draft,
                hidden_q_cur: &seq.prev_hidden_draft,
                p_prev: &seq.prev_p,
                q_prev: &seq.prev_q,
                q_root: &seq.prev_q,
                ctx_len: seq.tokens.len(),
                sampling: self.sampling,
            }))
        }
    }

    /// Generate up to `max_new` tokens with a fixed verifier and policy.
    pub fn generate(
        &self,
        prompt: &str,
        max_new: usize,
        verifier: &dyn Verifier,
        policy: &dyn ActionPolicy,
        rng: &mut Pcg64,
    ) -> Result<(String, GenStats)> {
        let mut seq = self.start(prompt)?;
        let mut stats = GenStats::default();
        let t0 = Instant::now();
        while !seq.finished && seq.tokens.len() - seq.prompt_len < max_new {
            let action = self.choose_action(&mut seq, policy)?;
            let b = self.step(&mut seq, verifier, action, rng)?;
            stats.add_block(&b);
        }
        stats.wall_secs = t0.elapsed().as_secs_f64();
        let text = tokenizer::decode(&seq.tokens[seq.prompt_len..]);
        Ok((text, stats))
    }

    /// Extra root draft pass for selector features (paper Appendix E: the
    /// draft-model forward at the root is cheap and supplies h^q_cur and
    /// H(q_root)).
    pub fn root_features(&self, seq: &mut Sequence) -> Result<RootFeatures> {
        let root = *seq.tokens.last().unwrap();
        let d = self.engine.decode(
            Role::Draft,
            seq.draft_kv.view(),
            root,
            seq.root_pos,
        )?;
        guard_finite(FaultOp::Decode, "root-feature decode logits", &d.logits)?;
        Ok(RootFeatures {
            hidden_q_cur: d.hidden,
            q_root: NodeDist::from_logits(&d.logits, self.sampling, DistStorage::global()),
        })
    }

    /// One plain autoregressive step on an in-flight sequence: a single
    /// target decode, sampled from the exact target distribution — the
    /// serving loop's lossless degraded mode when the speculative path
    /// (rollout / tree dispatches) is faulting. Also runs one draft decode
    /// so the sequence's draft cache stays row-complete: if the backend
    /// recovers and the lane switches back to speculation, drafting
    /// attends every committed position, exactly as if the tokens had been
    /// committed by speculative blocks. Rows are committed only after both
    /// dispatches pass the corruption guards, and the rng is consumed by
    /// exactly one draw per emitted token.
    pub fn step_autoregressive(&self, seq: &mut Sequence, rng: &mut Pcg64) -> Result<BlockStats> {
        let meta = self.engine.meta();
        if seq.root_pos + 2 >= meta.target.max_seq {
            seq.finished = true;
            return Ok(BlockStats::default());
        }
        let root = *seq.tokens.last().unwrap();
        let t0 = Instant::now();
        let out = self.engine.decode(Role::Target, seq.target_kv.view(), root, seq.root_pos)?;
        guard_finite(FaultOp::Decode, "target decode logits", &out.logits)?;
        let d = self.engine.decode(Role::Draft, seq.draft_kv.view(), root, seq.root_pos)?;
        guard_finite(FaultOp::Decode, "draft decode logits", &d.logits)?;
        seq.target_kv.commit_row(&out.k_row, &out.v_row, seq.root_pos);
        seq.draft_kv.commit_row(&d.k_row, &d.v_row, seq.root_pos);
        let p = NodeDist::from_logits(&out.logits, self.sampling, DistStorage::global());
        let tok = p.sample(rng) as u32;
        seq.tokens.push(tok);
        seq.root_pos += 1;
        if tokenizer::is_terminal(tok) || seq.root_pos + 2 >= meta.target.max_seq {
            seq.finished = true;
        }
        Ok(BlockStats {
            accepted: 0,
            emitted: 1,
            draft_secs: 0.0,
            tree_secs: t0.elapsed().as_secs_f64(),
            verify_secs: 0.0,
            tree_nodes: 0,
        })
    }
}

/// Root features needing a fresh draft pass.
pub struct RootFeatures {
    /// Draft-model hidden state at the current root.
    pub hidden_q_cur: Vec<f32>,
    /// Draft distribution at the current root.
    pub q_root: NodeDist,
}

impl RootFeatures {
    /// Assemble the full [`StepFeatures`] view over a sequence's memory.
    pub fn as_features<'a>(
        &'a self,
        seq: &'a Sequence,
        sampling: SamplingConfig,
    ) -> StepFeatures<'a> {
        StepFeatures {
            hidden_p_prev: &seq.prev_hidden_target,
            hidden_q_prev: &seq.prev_hidden_draft,
            hidden_q_cur: &self.hidden_q_cur,
            p_prev: &seq.prev_p,
            q_prev: &seq.prev_q,
            q_root: &self.q_root,
            ctx_len: seq.tokens.len(),
            sampling,
        }
    }
}

/// Whether a node's draft-KV row is absent from every rollout output: the
/// rollouts record rows only for nodes they *visited* (a node's row is
/// produced by the step that sampled its child), so the deepest node of a
/// trunk-only draft — and a branch node at its rollout's final bucket
/// position — has none. The trunk end is the exception *only in delayed
/// geometry*: there the branch rollout starts at the trunk end
/// (`branch_start == l1`) and its step 0 revisits it, supplying the row.
/// When the branches start at the root (root / greedy drafters) no rollout
/// revisits a fully-accepted trunk's end, and it back-fills like a
/// trunk-only draft.
fn draft_row_missing(
    tree: &DraftTree,
    drafted: &Drafted,
    node: usize,
) -> bool {
    use crate::tree::Provenance;
    match tree.nodes[node].provenance {
        Provenance::Root => false,
        Provenance::Trunk { step } => match &drafted.trunk {
            Some(tr) => {
                let branch_covers_end =
                    drafted.branch.is_some() && drafted.branch_start == tr.l;
                step >= tr.l && !(branch_covers_end && step == tr.l)
            }
            None => true,
        },
        Provenance::Branch { step, .. } => {
            drafted.branch.as_ref().is_none_or(|br| step >= br.l)
        }
    }
}

/// Draft hidden state for a tree node, if the rollouts computed one.
fn draft_hidden_for(
    tree: &DraftTree,
    drafted: &Drafted,
    node: usize,
    d_model: usize,
) -> Option<Vec<f32>> {
    use crate::tree::Provenance;
    match tree.nodes[node].provenance {
        Provenance::Root => drafted
            .trunk
            .as_ref()
            .map(|t| t.hiddens[0..d_model].to_vec())
            .or_else(|| drafted.branch.as_ref().map(|b| b.hiddens[0..d_model].to_vec())),
        Provenance::Trunk { step } => drafted.trunk.as_ref().and_then(|t| {
            if step < t.l {
                Some(t.hiddens[step * d_model..(step + 1) * d_model].to_vec())
            } else if drafted.branch_start == t.l {
                // trunk end in delayed geometry: the branch rollout visited
                // it at step 0
                drafted
                    .branch
                    .as_ref()
                    .map(|b| b.hiddens[0..d_model].to_vec())
            } else {
                // root-started branches never revisit the trunk end: keep
                // the previous feature memory (policy features only)
                None
            }
        }),
        Provenance::Branch { branch, step } => drafted.branch.as_ref().and_then(|b| {
            if step < b.l {
                let off = (branch * b.l + step) * d_model;
                Some(b.hiddens[off..off + d_model].to_vec())
            } else {
                None
            }
        }),
    }
}

/// Plain autoregressive decoding baseline (no speculation): one target
/// decode per token.
pub fn generate_autoregressive(
    engine: &dyn Backend,
    sampling: SamplingConfig,
    prompt: &str,
    max_new: usize,
    rng: &mut Pcg64,
) -> Result<(String, GenStats)> {
    let spec = SpecEngine::new(engine, sampling);
    let mut seq = spec.start(prompt)?;
    let mut stats = GenStats::default();
    let t0 = Instant::now();
    while !seq.finished && seq.tokens.len() - seq.prompt_len < max_new {
        let root = *seq.tokens.last().unwrap();
        let out = engine.decode(Role::Target, seq.target_kv.view(), root, seq.root_pos)?;
        guard_finite(FaultOp::Decode, "target decode logits", &out.logits)?;
        seq.target_kv.commit_row(&out.k_row, &out.v_row, seq.root_pos);
        let p = NodeDist::from_logits(&out.logits, sampling, DistStorage::global());
        let tok = p.sample(rng) as u32;
        seq.tokens.push(tok);
        seq.root_pos += 1;
        stats.blocks += 1;
        stats.tokens += 1;
        if tokenizer::is_terminal(tok) || seq.root_pos + 2 >= engine.meta().target.max_seq {
            seq.finished = true;
        }
    }
    stats.wall_secs = t0.elapsed().as_secs_f64();
    Ok((tokenizer::decode(&seq.tokens[seq.prompt_len..]), stats))
}
