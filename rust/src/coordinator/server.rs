//! Minimal TCP line-protocol serving front-end.
//!
//! Protocol: one JSON object per line in, one per line out.
//!   request:  {"prompt": "...", "max_new": 64, "temperature": 0.8,
//!              "top_p": 1.0, "verifier": "SpecInfer", "k": 2, "l1": 2, "l2": 4}
//!   response: {"text": "...", "tokens": n, "blocks": m, "tps": x,
//!              "block_efficiency": y}
//!
//! The listener accepts connections sequentially and processes requests in
//! arrival order — a deliberate single-lane scheduler matching the paper's
//! 1-core testbed. For concurrent multi-request serving use the batched
//! [`super::ServeLoop`] instead.

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};

use anyhow::{anyhow, Result};

use crate::coordinator::{FixedPolicy, SpecEngine};
use crate::dist::SamplingConfig;
use crate::draft::Action;
use crate::runtime::Backend;
use crate::util::json::{num, obj, s, Json};
use crate::util::Pcg64;
use crate::verify;

/// Listener configuration.
pub struct ServerConfig {
    /// Bind address, e.g. `127.0.0.1:7333`.
    pub addr: String,
    /// Seed of the server-wide rng stream.
    pub seed: u64,
}

/// Serve forever (or until `max_requests` when Some — used by tests).
pub fn serve(engine: &dyn Backend, cfg: &ServerConfig, max_requests: Option<usize>) -> Result<()> {
    let listener = TcpListener::bind(&cfg.addr)?;
    eprintln!("[specdelay] serving {} on {}", engine.meta().family, cfg.addr);
    let mut rng = Pcg64::seeded(cfg.seed);
    let mut served = 0usize;
    for stream in listener.incoming() {
        let stream = stream?;
        served += handle_conn(engine, stream, &mut rng)?;
        if let Some(m) = max_requests {
            if served >= m {
                break;
            }
        }
    }
    Ok(())
}

fn handle_conn(engine: &dyn Backend, stream: TcpStream, rng: &mut Pcg64) -> Result<usize> {
    let mut reader = BufReader::new(stream.try_clone()?);
    let mut out = stream;
    let mut line = String::new();
    let mut count = 0usize;
    loop {
        line.clear();
        if reader.read_line(&mut line)? == 0 {
            return Ok(count);
        }
        let reply = match handle_request(engine, line.trim(), rng) {
            Ok(j) => j,
            Err(e) => obj(vec![("error", s(&format!("{e}")))]),
        };
        writeln!(out, "{reply}")?;
        count += 1;
    }
}

fn handle_request(engine: &dyn Backend, line: &str, rng: &mut Pcg64) -> Result<Json> {
    let req = Json::parse(line).map_err(|e| anyhow!("bad json: {e}"))?;
    let prompt = req
        .get("prompt")
        .map_err(|e| anyhow!(e))?
        .as_str()
        .ok_or_else(|| anyhow!("prompt must be a string"))?
        .to_string();
    let gx = |k: &str, d: f64| req.get(k).ok().and_then(|v| v.as_f64()).unwrap_or(d);
    let sampling = SamplingConfig::new(gx("temperature", 1.0) as f32, gx("top_p", 1.0) as f32);
    let vname = req
        .get("verifier")
        .ok()
        .and_then(|v| v.as_str())
        .unwrap_or("SpecInfer")
        .to_string();
    let verifier =
        verify::verifier(&vname).ok_or_else(|| anyhow!("unknown verifier {vname}"))?;
    let action = Action::new(
        gx("k", 2.0) as usize,
        gx("l1", 2.0) as usize,
        gx("l2", 4.0) as usize,
    );
    let max_new = gx("max_new", 64.0) as usize;

    let spec = SpecEngine::new(engine, sampling);
    let (text, stats) =
        spec.generate(&prompt, max_new, verifier.as_ref(), &FixedPolicy(action), rng)?;
    Ok(obj(vec![
        ("text", s(&text)),
        ("tokens", num(stats.tokens as f64)),
        ("blocks", num(stats.blocks as f64)),
        ("tps", num(stats.tps())),
        ("block_efficiency", num(stats.block_efficiency())),
    ]))
}
