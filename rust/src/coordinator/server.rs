//! Minimal TCP line-protocol serving front-end.
//!
//! Protocol: one JSON object per line in, one per line out.
//!   request:  {"prompt": "...", "max_new": 64, "temperature": 0.8,
//!              "top_p": 1.0, "verifier": "SpecInfer", "k": 2, "l1": 2, "l2": 4,
//!              "drafter": "delayed|root|greedy",
//!              "priority": "high|normal|low", "deadline_ms": 250}
//!   response: {"text": "...", "tokens": n, "blocks": m, "tps": x,
//!              "block_efficiency": y, "priority": "...", "drafter": "...",
//!              "cached_prefix_rows": r (prompt rows adopted from the
//!              cross-request prefix cache; 0 when cold or disabled),
//!              "deadline_exceeded": bool (only when deadline_ms was set)}
//!
//! `priority` tags the request with a service class (the batched
//! [`super::ServeLoop`] scheduler's wire vocabulary; this single-lane
//! front-end serves in arrival order regardless, but validates and echoes
//! the class and accounts served requests per class). `deadline_ms`
//! bounds generation wall-clock from request start: the deadline is
//! checked between speculation blocks, so an expired request returns its
//! partial stream with `deadline_exceeded: true` within one block of the
//! limit instead of running to `max_new`.
//!
//! `drafter` picks the tree-shaping policy per request
//! ([`crate::draft::DrafterKind`], default `delayed`); every kind is
//! lossless, and the choice is echoed in the reply.
//!
//! A `{"stats": true}` line returns queue depths per priority class and
//! per-class served counts instead of generating — the lightweight
//! health/load probe:
//!   {"queued": {"high": 0, "normal": 0, "low": 0}, "active": 0,
//!    "served": {"high": h, "normal": n, "low": l},
//!    "drafter_blocks": {"delayed": d, "root": r, "greedy": g},
//!    "prefix_cache": {"lookups": ..., "hits": ..., "matched_rows": ...,
//!    "inserted_runs": ..., "evicted_blocks": ...,
//!    "reclaimed_under_pressure": ..., "skipped_contiguous": ...},
//!    "kv": {"storage": "paged"|"contiguous", "dtype": "f32"|"f16"|"int8",
//!    "capacity_multiplier": 1|2|4, "target_live_blocks": ...,
//!    "draft_live_blocks": ...}}
//! (depths are always zero here: this front-end has no queue — the
//! batched scheduler's [`super::ServeLoop::queued_by_class`] is the
//! populated counterpart; the prefix-cache object is all-zero unless
//! `SPECDELAY_PREFIX_CACHE=1` and the process runs paged storage).
//!
//! Every failure is answered with a structured error object rather than a
//! bare string (or a dropped connection):
//!   error:    {"error": {"kind": "...", "message": "..."}}
//! with stable kinds `bad_json` (unparseable line), `bad_request` (wrong
//! shape, e.g. missing prompt), `bad_params` (out-of-range or non-numeric
//! sampling parameters), `unknown_verifier`, `oversized_line` (longer than
//! [`ServerConfig::max_line_bytes`]; the rest of the line is drained and
//! the connection survives), `too_many_requests` (the per-connection cap
//! [`ServerConfig::max_requests_per_conn`] was hit; the connection closes
//! after the reply) and `generation` (the backend failed mid-generation).
//!
//! Slow or stalled clients are bounded by [`ServerConfig::read_timeout`] /
//! [`ServerConfig::write_timeout`]: an idle connection is closed (without
//! tearing down the listener) instead of wedging the single-lane server
//! forever. Oversized lines are skipped in bounded chunks — a client
//! streaming an endless line can never balloon server memory past the cap.
//!
//! The listener accepts connections sequentially and processes requests in
//! arrival order — a deliberate single-lane scheduler matching the paper's
//! 1-core testbed. For concurrent multi-request serving use the batched
//! [`super::ServeLoop`] instead.

use std::io::{BufRead, BufReader, ErrorKind, Read, Write};
use std::net::TcpListener;
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::coordinator::{FixedPolicy, GenStats, KvPools, Priority, SpecEngine};
use crate::dist::SamplingConfig;
use crate::draft::{Action, DrafterKind};
use crate::kvcache::{prefix_cache_enabled, KvDtype, KvStorage, PrefixCache};
use crate::runtime::Backend;
use crate::tokenizer;
use crate::util::json::{num, obj, s, Json};
use crate::util::Pcg64;
use crate::verify;

/// Per-class service accounting for one server process (reported by the
/// `stats` request).
#[derive(Clone, Debug, Default)]
pub struct ServeStats {
    /// Requests generated to completion, per [`Priority::index`] class.
    pub served: [u64; 3],
    /// Speculation blocks run, per [`DrafterKind::index`] — which drafting
    /// policies this process's traffic actually exercised.
    pub drafter_blocks: [u64; 3],
    /// Requests that wanted the prefix cache but ran without one because
    /// the process uses contiguous KV storage (folded into the stats
    /// reply's `skipped_contiguous`).
    pub prefix_skipped: u64,
}

/// Cross-request prefix-cache state: one shared pool pair plus the radix
/// cache indexing it, kept alive across the per-request engines (each
/// request adopts the pools via [`SpecEngine::with_kv_pools`], so cached
/// blocks stay valid between requests). `None` when prefix caching is
/// disabled or the process runs contiguous storage — requests then prefill
/// cold, exactly as before.
struct WarmState {
    pools: KvPools,
    cache: PrefixCache,
}

/// Build the server's warm state when the `SPECDELAY_PREFIX_CACHE` knob is
/// on and the process-wide storage is paged.
fn warm_state(engine: &dyn Backend) -> Option<WarmState> {
    if !prefix_cache_enabled() || !matches!(KvStorage::global(), KvStorage::Paged) {
        return None;
    }
    // a throwaway engine materialises the pool pair for this backend's
    // dimensions; sampling is irrelevant to storage
    let probe = SpecEngine::new(engine, SamplingConfig::new(1.0, 1.0));
    let pools = probe.kv_pools()?.clone();
    let cache = PrefixCache::new(&pools.target, &pools.draft);
    Some(WarmState { pools, cache })
}

/// Listener configuration.
pub struct ServerConfig {
    /// Bind address, e.g. `127.0.0.1:7333`.
    pub addr: String,
    /// Seed of the server-wide rng stream.
    pub seed: u64,
    /// Per-read socket timeout; an idle connection is closed (the listener
    /// keeps serving). `None` waits forever.
    pub read_timeout: Option<Duration>,
    /// Per-write socket timeout; a stalled client is disconnected rather
    /// than wedging the server.
    pub write_timeout: Option<Duration>,
    /// Longest accepted request line in bytes; longer lines are answered
    /// with an `oversized_line` error and skipped in bounded chunks.
    pub max_line_bytes: usize,
    /// Requests served per connection before a `too_many_requests` reply
    /// closes it.
    pub max_requests_per_conn: usize,
}

impl ServerConfig {
    /// Config with the given bind address and rng seed and hardened
    /// defaults for everything else (30 s socket timeouts, 64 KiB line
    /// cap, 1024 requests per connection).
    pub fn new(addr: impl Into<String>, seed: u64) -> ServerConfig {
        ServerConfig { addr: addr.into(), seed, ..ServerConfig::default() }
    }
}

impl Default for ServerConfig {
    fn default() -> ServerConfig {
        ServerConfig {
            addr: "127.0.0.1:7333".to_string(),
            seed: 0,
            read_timeout: Some(Duration::from_secs(30)),
            write_timeout: Some(Duration::from_secs(30)),
            max_line_bytes: 64 * 1024,
            max_requests_per_conn: 1024,
        }
    }
}

/// A structured request-level failure: the stable `kind` tag plus a
/// human-readable message, rendered as the protocol's error object.
struct ReqError {
    kind: &'static str,
    message: String,
}

impl ReqError {
    fn new(kind: &'static str, message: impl Into<String>) -> ReqError {
        ReqError { kind, message: message.into() }
    }
}

/// The protocol's error reply: `{"error": {"kind": ..., "message": ...}}`.
fn error_reply(kind: &str, message: &str) -> Json {
    obj(vec![("error", obj(vec![("kind", s(kind)), ("message", s(message))]))])
}

/// Serve forever (or until `max_requests` when Some — used by tests).
pub fn serve(engine: &dyn Backend, cfg: &ServerConfig, max_requests: Option<usize>) -> Result<()> {
    let listener = TcpListener::bind(&cfg.addr)?;
    eprintln!("[specdelay] serving {} on {}", engine.meta().family, cfg.addr);
    let mut rng = Pcg64::seeded(cfg.seed);
    let mut served = 0usize;
    let mut stats = ServeStats::default();
    let mut warm = warm_state(engine);
    for stream in listener.incoming() {
        let stream = stream?;
        stream.set_read_timeout(cfg.read_timeout)?;
        stream.set_write_timeout(cfg.write_timeout)?;
        let mut reader = BufReader::new(stream.try_clone()?);
        let mut out = stream;
        served +=
            handle_conn(engine, &mut reader, &mut out, cfg, &mut rng, &mut stats, &mut warm)?;
        if let Some(m) = max_requests {
            if served >= m {
                break;
            }
        }
    }
    Ok(())
}

/// Outcome of one capped line read.
enum LineRead {
    /// Clean end of stream.
    Eof,
    /// A complete line within the cap (trailing newline stripped by caller).
    Line,
    /// The line exceeded the cap; its remainder was drained in bounded
    /// chunks and the reader stands at the start of the next line.
    Oversized,
}

/// Read one `\n`-terminated line of at most `cap` bytes. Oversized lines
/// are consumed to their newline through the BufRead buffer (bounded
/// memory: at most `cap` + one buffer's worth resident at a time).
fn read_capped_line<R: BufRead>(
    reader: &mut R,
    buf: &mut String,
    cap: usize,
) -> std::io::Result<LineRead> {
    buf.clear();
    let n = (&mut *reader).take(cap as u64 + 1).read_line(buf)?;
    if n == 0 {
        return Ok(LineRead::Eof);
    }
    // newline within the window = complete line (content ≤ cap bytes);
    // n ≤ cap without one = EOF-terminated final line, also complete
    if buf.ends_with('\n') || n <= cap {
        return Ok(LineRead::Line);
    }
    // over the cap: drop what we buffered and skip to the newline
    buf.clear();
    loop {
        let (done, used) = {
            let chunk = reader.fill_buf()?;
            if chunk.is_empty() {
                break; // EOF mid-line
            }
            match chunk.iter().position(|&b| b == b'\n') {
                Some(i) => (true, i + 1),
                None => (false, chunk.len()),
            }
        };
        reader.consume(used);
        if done {
            break;
        }
    }
    Ok(LineRead::Oversized)
}

/// True for the error kinds socket timeouts surface as (platform-dependent
/// which of the two).
fn is_timeout(e: &std::io::Error) -> bool {
    matches!(e.kind(), ErrorKind::WouldBlock | ErrorKind::TimedOut)
}

/// Serve one connection: returns the number of requests answered.
/// Read/write timeouts and disconnects close this connection gracefully
/// (never the listener); malformed requests are answered with structured
/// errors and the connection survives.
fn handle_conn<R: BufRead, W: Write>(
    engine: &dyn Backend,
    reader: &mut R,
    out: &mut W,
    cfg: &ServerConfig,
    rng: &mut Pcg64,
    stats: &mut ServeStats,
    warm: &mut Option<WarmState>,
) -> Result<usize> {
    let mut line = String::new();
    let mut count = 0usize;
    loop {
        let read = match read_capped_line(reader, &mut line, cfg.max_line_bytes) {
            Ok(r) => r,
            Err(e) if is_timeout(&e) => return Ok(count), // idle client: close
            Err(e) if e.kind() == ErrorKind::InvalidData => {
                // non-UTF-8 bytes: reply once, then close (the stream
                // position within the garbage is unknowable)
                let reply = error_reply("bad_request", "request line is not valid UTF-8");
                let _ = writeln!(out, "{reply}");
                return Ok(count);
            }
            Err(e) => return Err(anyhow::Error::new(e)),
        };
        let reply = match read {
            LineRead::Eof => return Ok(count),
            LineRead::Oversized => error_reply(
                "oversized_line",
                &format!("request line exceeds {} bytes", cfg.max_line_bytes),
            ),
            LineRead::Line => {
                if count >= cfg.max_requests_per_conn {
                    // enriched overload error: how much work this
                    // connection already got, that nothing is queued
                    // behind it, and that an immediate reconnect (which
                    // resets the per-connection cap) is fine
                    let reply = obj(vec![(
                        "error",
                        obj(vec![
                            ("kind", s("too_many_requests")),
                            (
                                "message",
                                s(&format!(
                                    "connection served {count} requests; reconnect to continue"
                                )),
                            ),
                            ("queued", num(0.0)),
                            ("active", num(0.0)),
                            ("retry_after_hint_ms", num(0.0)),
                        ]),
                    )]);
                    let _ = writeln!(out, "{reply}");
                    return Ok(count);
                }
                match handle_request(engine, line.trim(), rng, stats, warm) {
                    Ok(j) => j,
                    Err(e) => error_reply(e.kind, &e.message),
                }
            }
        };
        match writeln!(out, "{reply}") {
            Ok(()) => {}
            Err(e) if is_timeout(&e) || e.kind() == ErrorKind::BrokenPipe => return Ok(count),
            Err(e) => return Err(anyhow::Error::new(e)),
        }
        count += 1;
    }
}

/// A numeric parameter with a default and an inclusive validity range;
/// present-but-non-numeric and out-of-range values are `bad_params`.
fn num_param(req: &Json, key: &str, default: f64, lo: f64, hi: f64) -> Result<f64, ReqError> {
    let Ok(v) = req.get(key) else { return Ok(default) };
    match v.as_f64() {
        None => Err(ReqError::new("bad_params", format!("{key} must be a number"))),
        Some(x) if !(lo..=hi).contains(&x) => Err(ReqError::new(
            "bad_params",
            format!("{key} = {x} out of range [{lo}, {hi}]"),
        )),
        Some(x) => Ok(x),
    }
}

/// The `{"stats": true}` reply: per-class queue depths (always zero for
/// this queueless front-end — wire-compatible with the batched
/// scheduler's), in-flight lane count, per-class served totals, and the
/// prefix-cache counters (all-zero when the cache never materialised).
fn stats_reply(stats: &ServeStats, warm: &Option<WarmState>) -> Json {
    let class = |v: [f64; 3]| {
        obj(vec![("high", num(v[0])), ("normal", num(v[1])), ("low", num(v[2]))])
    };
    let mut c = warm.as_ref().map(|w| w.cache.counters()).unwrap_or_default();
    c.skipped_contiguous += stats.prefix_skipped;
    obj(vec![
        ("queued", class([0.0, 0.0, 0.0])),
        ("active", num(0.0)),
        ("served", class([stats.served[0] as f64, stats.served[1] as f64, stats.served[2] as f64])),
        (
            "drafter_blocks",
            obj(DrafterKind::ALL
                .into_iter()
                .map(|k| (k.name(), num(stats.drafter_blocks[k.index()] as f64)))
                .collect()),
        ),
        (
            "prefix_cache",
            obj(vec![
                ("lookups", num(c.lookups as f64)),
                ("hits", num(c.hits as f64)),
                ("matched_rows", num(c.matched_rows as f64)),
                ("inserted_runs", num(c.inserted_runs as f64)),
                ("evicted_blocks", num(c.evicted_blocks as f64)),
                ("reclaimed_under_pressure", num(c.reclaimed_under_pressure as f64)),
                ("skipped_contiguous", num(c.skipped_contiguous as f64)),
            ]),
        ),
        (
            "kv",
            obj(vec![
                (
                    "storage",
                    s(match KvStorage::global() {
                        KvStorage::Paged => "paged",
                        KvStorage::Contiguous => "contiguous",
                    }),
                ),
                ("dtype", s(KvDtype::global().name())),
                (
                    "capacity_multiplier",
                    num(KvDtype::global().capacity_multiplier() as f64),
                ),
                (
                    "target_live_blocks",
                    num(warm.as_ref().map(|w| w.pools.target.live_blocks()).unwrap_or(0) as f64),
                ),
                (
                    "draft_live_blocks",
                    num(warm.as_ref().map(|w| w.pools.draft.live_blocks()).unwrap_or(0) as f64),
                ),
            ]),
        ),
    ])
}

fn handle_request(
    engine: &dyn Backend,
    line: &str,
    rng: &mut Pcg64,
    stats: &mut ServeStats,
    warm: &mut Option<WarmState>,
) -> Result<Json, ReqError> {
    let req = Json::parse(line).map_err(|e| ReqError::new("bad_json", format!("bad json: {e}")))?;
    if req.get("stats").is_ok() {
        return Ok(stats_reply(stats, warm));
    }
    let prompt = req
        .get("prompt")
        .map_err(|e| ReqError::new("bad_request", e))?
        .as_str()
        .ok_or_else(|| ReqError::new("bad_request", "prompt must be a string"))?
        .to_string();
    let priority = match req.get("priority").ok().map(|p| p.as_str().map(|v| v.to_string())) {
        None => Priority::Normal,
        Some(Some(name)) => Priority::parse(&name).ok_or_else(|| {
            ReqError::new("bad_params", format!("priority must be high|normal|low, got {name}"))
        })?,
        Some(None) => {
            return Err(ReqError::new("bad_params", "priority must be a string"));
        }
    };
    let drafter = match req.get("drafter").ok().map(|d| d.as_str().map(|v| v.to_string())) {
        None => DrafterKind::default(),
        Some(Some(name)) => DrafterKind::parse(&name).ok_or_else(|| {
            ReqError::new("bad_params", format!("drafter must be delayed|root|greedy, got {name}"))
        })?,
        Some(None) => {
            return Err(ReqError::new("bad_params", "drafter must be a string"));
        }
    };
    let temperature = num_param(&req, "temperature", 1.0, 0.0, 16.0)? as f32;
    let top_p = num_param(&req, "top_p", 1.0, 0.0, 1.0)? as f32;
    if top_p <= 0.0 {
        return Err(ReqError::new("bad_params", "top_p must be in (0, 1]"));
    }
    let sampling = SamplingConfig::new(temperature, top_p);
    let vname = req
        .get("verifier")
        .ok()
        .and_then(|v| v.as_str())
        .unwrap_or("SpecInfer")
        .to_string();
    let verifier = verify::verifier(&vname)
        .ok_or_else(|| ReqError::new("unknown_verifier", format!("unknown verifier {vname}")))?;
    let action = Action::new(
        num_param(&req, "k", 2.0, 1.0, 64.0)? as usize,
        num_param(&req, "l1", 2.0, 0.0, 64.0)? as usize,
        num_param(&req, "l2", 4.0, 0.0, 64.0)? as usize,
    );
    let max_new = num_param(&req, "max_new", 64.0, 1.0, 4096.0)? as usize;
    let deadline_ms = num_param(&req, "deadline_ms", 0.0, 0.0, 3_600_000.0)?;
    let deadline =
        (deadline_ms > 0.0).then(|| Duration::from_micros((deadline_ms * 1000.0) as u64));

    let gen_err = |e: anyhow::Error| ReqError::new("generation", e.to_string());
    let mut spec = SpecEngine::new(engine, sampling).with_drafter(drafter);
    if let Some(w) = warm.as_ref() {
        // share the server-wide pool pair so this request can adopt (and
        // later publish) cached prefix blocks
        spec = spec.with_kv_pools(w.pools.clone());
    }
    let policy = FixedPolicy(action);
    // the exact per-block loop of `SpecEngine::generate` (same rng
    // consumption, so streams match a plain generate call), with the
    // deadline checked between blocks: an expired request returns its
    // partial stream within one block of the limit
    let started = Instant::now();
    let (mut seq, cached_rows) = match warm.as_mut() {
        Some(w) => {
            // warm prefill: adopt the longest cached block run, then
            // prefill only the uncached tail — chunked rows are
            // bit-identical to the one-shot `start`, so the stream (and
            // the rng consumption after it) is unchanged
            let mut st = spec.start_chunked_cached(&prompt, &mut w.cache);
            let cached = st.rows_done();
            while !spec.prefill_step(&mut st, usize::MAX).map_err(gen_err)? {}
            (spec.finish_prefill(st).map_err(gen_err)?, cached)
        }
        None => {
            if prefix_cache_enabled() {
                // knob on but contiguous storage: graceful cold fallback
                stats.prefix_skipped += 1;
            }
            (spec.start(&prompt).map_err(gen_err)?, 0)
        }
    };
    let mut gstats = GenStats::default();
    let mut exceeded = false;
    while !(seq.finished || seq.tokens.len() - seq.prompt_len >= max_new) {
        if deadline.is_some_and(|d| started.elapsed() >= d) {
            exceeded = true;
            break;
        }
        let a = spec.choose_action(&mut seq, &policy).map_err(gen_err)?;
        let b = spec.step(&mut seq, verifier.as_ref(), a, rng).map_err(gen_err)?;
        gstats.add_block(&b);
    }
    gstats.wall_secs = started.elapsed().as_secs_f64();
    if let Some(w) = warm.as_mut() {
        // publish the finished request's committed prefix for future
        // requests sharing it (error paths above returned early, so only
        // whole, fault-free caches are ever inserted)
        if let (Some(t), Some(d)) = (seq.target_kv.as_paged(), seq.draft_kv.as_paged()) {
            w.cache.insert(&seq.tokens[..seq.root_pos], t, d);
        }
    }
    let text = tokenizer::decode(&seq.tokens[seq.prompt_len..]);
    stats.served[priority.index()] += 1;
    stats.drafter_blocks[drafter.index()] += gstats.blocks as u64;
    let mut fields = vec![
        ("text", s(&text)),
        ("tokens", num(gstats.tokens as f64)),
        ("blocks", num(gstats.blocks as f64)),
        ("tps", num(gstats.tps())),
        ("block_efficiency", num(gstats.block_efficiency())),
        ("priority", s(priority.name())),
        ("drafter", s(drafter.name())),
        ("cached_prefix_rows", num(cached_rows as f64)),
    ];
    if deadline.is_some() {
        fields.push(("deadline_exceeded", Json::Bool(exceeded)));
    }
    Ok(obj(fields))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::{CpuModelConfig, CpuRefBackend};
    use std::io::Cursor;

    fn backend() -> CpuRefBackend {
        CpuRefBackend::new(&CpuModelConfig::tiny(), 11)
    }

    fn request(engine: &dyn Backend, line: &str) -> Json {
        let mut rng = Pcg64::seeded(0);
        let mut stats = ServeStats::default();
        match handle_request(engine, line, &mut rng, &mut stats, &mut None) {
            Ok(j) => j,
            Err(e) => error_reply(e.kind, &e.message),
        }
    }

    /// A warm state over explicit paged pools, independent of the
    /// process-wide storage knob.
    fn forced_warm(engine: &dyn Backend) -> Option<WarmState> {
        let probe =
            SpecEngine::new(engine, SamplingConfig::new(1.0, 1.0)).with_paged_kv(16, None);
        let pools = probe.kv_pools().expect("paged engine has pools").clone();
        let cache = PrefixCache::new(&pools.target, &pools.draft);
        Some(WarmState { pools, cache })
    }

    fn error_kind(j: &Json) -> Option<String> {
        j.get("error")
            .ok()
            .and_then(|e| e.get("kind").ok())
            .and_then(|k| k.as_str())
            .map(|k| k.to_string())
    }

    #[test]
    fn malformed_json_is_structured_bad_json() {
        let b = backend();
        let j = request(&b, "{not json");
        assert_eq!(error_kind(&j).as_deref(), Some("bad_json"));
        let msg = j.get("error").unwrap().get("message").unwrap().as_str().unwrap().to_string();
        assert!(msg.contains("bad json"), "{msg}");
    }

    #[test]
    fn missing_or_nonstring_prompt_is_bad_request() {
        let b = backend();
        let j = request(&b, r#"{"max_new": 4}"#);
        assert_eq!(error_kind(&j).as_deref(), Some("bad_request"));
        let j = request(&b, r#"{"prompt": 7}"#);
        assert_eq!(error_kind(&j).as_deref(), Some("bad_request"));
    }

    #[test]
    fn unknown_verifier_is_structured() {
        let b = backend();
        let j = request(&b, r#"{"prompt": "hi", "verifier": "NotAVerifier"}"#);
        assert_eq!(error_kind(&j).as_deref(), Some("unknown_verifier"));
        let msg = j.get("error").unwrap().get("message").unwrap().as_str().unwrap().to_string();
        assert!(msg.contains("NotAVerifier"), "{msg}");
    }

    #[test]
    fn out_of_range_and_nonnumeric_params_are_bad_params() {
        let b = backend();
        for line in [
            r#"{"prompt": "hi", "top_p": 0.0}"#,
            r#"{"prompt": "hi", "top_p": 1.5}"#,
            r#"{"prompt": "hi", "temperature": -1}"#,
            r#"{"prompt": "hi", "temperature": "hot"}"#,
            r#"{"prompt": "hi", "max_new": 0}"#,
            r#"{"prompt": "hi", "max_new": 100000}"#,
            r#"{"prompt": "hi", "k": 0}"#,
            r#"{"prompt": "hi", "l1": -3}"#,
        ] {
            let j = request(&b, line);
            assert_eq!(error_kind(&j).as_deref(), Some("bad_params"), "line: {line}");
        }
    }

    #[test]
    fn valid_request_generates() {
        let b = backend();
        let j = request(&b, r#"{"prompt": "2+2= ", "max_new": 4, "temperature": 0}"#);
        assert!(error_kind(&j).is_none(), "{j}");
        assert!(j.get("text").unwrap().as_str().is_some());
        assert!(j.get("tokens").unwrap().as_f64().unwrap() >= 1.0);
    }

    #[test]
    fn oversized_line_replies_and_connection_survives() {
        let b = backend();
        let mut cfg = ServerConfig::new("unused", 0);
        cfg.max_line_bytes = 64;
        let huge = format!("{{\"prompt\": \"{}\"}}\n", "x".repeat(500));
        let follow = r#"{"prompt": "2+2= ", "max_new": 2, "temperature": 0}"#;
        let input = format!("{huge}{follow}\n");
        let mut reader = Cursor::new(input.into_bytes());
        let mut out: Vec<u8> = Vec::new();
        let mut rng = Pcg64::seeded(0);
        let served = handle_conn(&b, &mut reader, &mut out, &cfg, &mut rng, &mut ServeStats::default(), &mut None).unwrap();
        assert_eq!(served, 2);
        let text = String::from_utf8(out).unwrap();
        let replies: Vec<&str> = text.lines().collect();
        assert_eq!(replies.len(), 2, "{text}");
        let first = Json::parse(replies[0]).unwrap();
        assert_eq!(error_kind(&first).as_deref(), Some("oversized_line"));
        let second = Json::parse(replies[1]).unwrap();
        assert!(error_kind(&second).is_none(), "{text}");
    }

    #[test]
    fn per_connection_request_cap_closes_with_structured_error() {
        let b = backend();
        let mut cfg = ServerConfig::new("unused", 0);
        cfg.max_requests_per_conn = 2;
        let line = r#"{"prompt": "2+2= ", "max_new": 2, "temperature": 0}"#;
        let input = format!("{line}\n{line}\n{line}\n{line}\n");
        let mut reader = Cursor::new(input.into_bytes());
        let mut out: Vec<u8> = Vec::new();
        let mut rng = Pcg64::seeded(0);
        let served = handle_conn(&b, &mut reader, &mut out, &cfg, &mut rng, &mut ServeStats::default(), &mut None).unwrap();
        assert_eq!(served, 2);
        let text = String::from_utf8(out).unwrap();
        let replies: Vec<&str> = text.lines().collect();
        assert_eq!(replies.len(), 3, "{text}");
        let last = Json::parse(replies[2]).unwrap();
        assert_eq!(error_kind(&last).as_deref(), Some("too_many_requests"));
    }

    #[test]
    fn priority_is_validated_and_echoed() {
        let b = backend();
        let j = request(&b, r#"{"prompt": "2+2= ", "max_new": 2, "priority": "high"}"#);
        assert!(error_kind(&j).is_none(), "{j}");
        assert_eq!(j.get("priority").unwrap().as_str(), Some("high"));
        // default class when omitted
        let j = request(&b, r#"{"prompt": "2+2= ", "max_new": 2}"#);
        assert_eq!(j.get("priority").unwrap().as_str(), Some("normal"));
        // junk class and non-string class are bad_params
        for line in [
            r#"{"prompt": "hi", "priority": "urgent"}"#,
            r#"{"prompt": "hi", "priority": 3}"#,
        ] {
            let j = request(&b, line);
            assert_eq!(error_kind(&j).as_deref(), Some("bad_params"), "line: {line}");
        }
    }

    #[test]
    fn drafter_is_validated_and_echoed() {
        let b = backend();
        for name in ["delayed", "root", "greedy"] {
            let line =
                format!(r#"{{"prompt": "2+2= ", "max_new": 2, "drafter": "{name}"}}"#);
            let j = request(&b, &line);
            assert!(error_kind(&j).is_none(), "{j}");
            assert_eq!(j.get("drafter").unwrap().as_str(), Some(name));
            assert!(j.get("tokens").unwrap().as_f64().unwrap() >= 1.0);
        }
        // default kind when omitted
        let j = request(&b, r#"{"prompt": "2+2= ", "max_new": 2}"#);
        assert_eq!(j.get("drafter").unwrap().as_str(), Some("delayed"));
        // junk kind and non-string kind are bad_params
        for line in [
            r#"{"prompt": "hi", "drafter": "eager"}"#,
            r#"{"prompt": "hi", "drafter": 1}"#,
        ] {
            let j = request(&b, line);
            assert_eq!(error_kind(&j).as_deref(), Some("bad_params"), "line: {line}");
        }
    }

    #[test]
    fn stats_reply_reports_per_drafter_blocks() {
        let b = backend();
        let mut rng = Pcg64::seeded(0);
        let mut stats = ServeStats::default();
        let root = r#"{"prompt": "2+2= ", "max_new": 2, "drafter": "root"}"#;
        let plain = r#"{"prompt": "2+2= ", "max_new": 2}"#;
        handle_request(&b, root, &mut rng, &mut stats, &mut None).unwrap();
        handle_request(&b, plain, &mut rng, &mut stats, &mut None).unwrap();
        let j = handle_request(&b, r#"{"stats": true}"#, &mut rng, &mut stats, &mut None).unwrap();
        let db = j.get("drafter_blocks").unwrap();
        assert!(db.get("root").unwrap().as_f64().unwrap() >= 1.0, "{j}");
        assert!(db.get("delayed").unwrap().as_f64().unwrap() >= 1.0, "{j}");
        assert_eq!(db.get("greedy").unwrap().as_f64(), Some(0.0), "{j}");
    }

    #[test]
    fn stats_reply_reports_kv_config() {
        let b = backend();
        let mut rng = Pcg64::seeded(0);
        let mut stats = ServeStats::default();
        let j = handle_request(&b, r#"{"stats": true}"#, &mut rng, &mut stats, &mut None).unwrap();
        let kv = j.get("kv").unwrap();
        // the process-global knobs are unset in tier-1 runs; under the CI
        // dtype matrix these echo the selected configuration
        let storage = kv.get("storage").unwrap().as_str().unwrap().to_string();
        assert!(storage == "paged" || storage == "contiguous", "{j}");
        let dtype = kv.get("dtype").unwrap().as_str().unwrap().to_string();
        let mult = kv.get("capacity_multiplier").unwrap().as_f64().unwrap();
        let want = match dtype.as_str() {
            "f32" => 1.0,
            "f16" => 2.0,
            "int8" => 4.0,
            other => panic!("unexpected dtype {other}"),
        };
        assert_eq!(mult, want, "{j}");
        assert!(kv.get("target_live_blocks").unwrap().as_f64().is_some(), "{j}");
        assert!(kv.get("draft_live_blocks").unwrap().as_f64().is_some(), "{j}");
    }

    #[test]
    fn deadline_ms_bounds_generation_and_is_reported() {
        let b = backend();
        // a deadline that has effectively already passed: partial (here
        // empty) stream plus the exceeded flag, not an error
        let j = request(&b, r#"{"prompt": "2+2= ", "max_new": 64, "deadline_ms": 0.001}"#);
        assert!(error_kind(&j).is_none(), "{j}");
        assert_eq!(j.get("deadline_exceeded").unwrap(), &Json::Bool(true));
        // a generous deadline finishes and reports false
        let j = request(
            &b,
            r#"{"prompt": "2+2= ", "max_new": 2, "deadline_ms": 60000, "temperature": 0}"#,
        );
        assert!(error_kind(&j).is_none(), "{j}");
        assert_eq!(j.get("deadline_exceeded").unwrap(), &Json::Bool(false));
        assert!(j.get("tokens").unwrap().as_f64().unwrap() >= 1.0);
        // no deadline → no flag in the reply
        let j = request(&b, r#"{"prompt": "2+2= ", "max_new": 2, "temperature": 0}"#);
        assert!(j.get("deadline_exceeded").is_err(), "{j}");
    }

    #[test]
    fn stats_request_reports_class_depths_and_served_counts() {
        let b = backend();
        let mut rng = Pcg64::seeded(0);
        let mut stats = ServeStats::default();
        let gen = r#"{"prompt": "2+2= ", "max_new": 2, "priority": "low"}"#;
        handle_request(&b, gen, &mut rng, &mut stats, &mut None).unwrap();
        handle_request(&b, gen, &mut rng, &mut stats, &mut None).unwrap();
        let j = handle_request(&b, r#"{"stats": true}"#, &mut rng, &mut stats, &mut None).unwrap();
        let queued = j.get("queued").unwrap();
        for class in ["high", "normal", "low"] {
            assert_eq!(queued.get(class).unwrap().as_f64(), Some(0.0), "{j}");
        }
        assert_eq!(j.get("active").unwrap().as_f64(), Some(0.0));
        let served = j.get("served").unwrap();
        assert_eq!(served.get("low").unwrap().as_f64(), Some(2.0), "{j}");
        assert_eq!(served.get("high").unwrap().as_f64(), Some(0.0), "{j}");
        // a stats probe is not itself a served generation
        assert!(j.get("text").is_err());
    }

    #[test]
    fn request_cap_reply_carries_load_fields() {
        let b = backend();
        let mut cfg = ServerConfig::new("unused", 0);
        cfg.max_requests_per_conn = 1;
        let line = r#"{"prompt": "2+2= ", "max_new": 2, "temperature": 0}"#;
        let input = format!("{line}\n{line}\n");
        let mut reader = Cursor::new(input.into_bytes());
        let mut out: Vec<u8> = Vec::new();
        let mut rng = Pcg64::seeded(0);
        let served =
            handle_conn(&b, &mut reader, &mut out, &cfg, &mut rng, &mut ServeStats::default(), &mut None)
                .unwrap();
        assert_eq!(served, 1);
        let text = String::from_utf8(out).unwrap();
        let last = Json::parse(text.lines().last().unwrap()).unwrap();
        assert_eq!(error_kind(&last).as_deref(), Some("too_many_requests"));
        let err = last.get("error").unwrap();
        assert_eq!(err.get("queued").unwrap().as_f64(), Some(0.0));
        assert_eq!(err.get("active").unwrap().as_f64(), Some(0.0));
        assert!(err.get("retry_after_hint_ms").unwrap().as_f64().is_some());
    }

    #[test]
    fn non_utf8_line_replies_then_closes() {
        let b = backend();
        let cfg = ServerConfig::new("unused", 0);
        let mut bytes = vec![b'{', 0xFF, 0xFE, b'}'];
        bytes.push(b'\n');
        let mut reader = Cursor::new(bytes);
        let mut out: Vec<u8> = Vec::new();
        let mut rng = Pcg64::seeded(0);
        let served = handle_conn(&b, &mut reader, &mut out, &cfg, &mut rng, &mut ServeStats::default(), &mut None).unwrap();
        assert_eq!(served, 0);
        let text = String::from_utf8(out).unwrap();
        let j = Json::parse(text.trim()).unwrap();
        assert_eq!(error_kind(&j).as_deref(), Some("bad_request"));
    }

    #[test]
    fn warm_repeat_request_hits_cache_and_stream_is_unchanged() {
        let b = backend();
        let line = r#"{"prompt": "12*12*12*12*12*12= ", "max_new": 6, "temperature": 0}"#;
        // cold oracle: no warm state at all
        let cold = request(&b, line);
        assert!(error_kind(&cold).is_none(), "{cold}");
        assert_eq!(cold.get("cached_prefix_rows").unwrap().as_f64(), Some(0.0));
        // warm server: identical request twice against one shared cache
        let mut warm = forced_warm(&b);
        let mut stats = ServeStats::default();
        let mut rng = Pcg64::seeded(0);
        let first = handle_request(&b, line, &mut rng, &mut stats, &mut warm).unwrap();
        let mut rng = Pcg64::seeded(0);
        let second = handle_request(&b, line, &mut rng, &mut stats, &mut warm).unwrap();
        // bit-identical text across cold, warm-miss and warm-hit runs
        let text = |j: &Json| j.get("text").unwrap().as_str().unwrap().to_string();
        assert_eq!(text(&cold), text(&first));
        assert_eq!(text(&cold), text(&second));
        assert_eq!(first.get("cached_prefix_rows").unwrap().as_f64(), Some(0.0));
        // the prompt tokenizes to 20 tokens with BOS, so the repeat
        // adopts at least one whole cached block of 16
        let hit = second.get("cached_prefix_rows").unwrap().as_f64().unwrap();
        assert!(hit >= 16.0, "expected a block-aligned hit, got {hit}");
        let w = warm.as_ref().unwrap();
        let c = w.cache.counters();
        assert_eq!(c.lookups, 2);
        assert_eq!(c.hits, 1);
        assert!(c.matched_rows as f64 >= hit);
        assert!(c.inserted_runs >= 1);
    }

    #[test]
    fn stats_reply_reports_prefix_cache_counters() {
        let b = backend();
        let mut warm = forced_warm(&b);
        let mut stats = ServeStats::default();
        let mut rng = Pcg64::seeded(0);
        let line = r#"{"prompt": "12*12*12*12*12*12= ", "max_new": 4, "temperature": 0}"#;
        handle_request(&b, line, &mut rng, &mut stats, &mut warm).unwrap();
        handle_request(&b, line, &mut rng, &mut stats, &mut warm).unwrap();
        let j = handle_request(&b, r#"{"stats": true}"#, &mut rng, &mut stats, &mut warm)
            .unwrap();
        let pc = j.get("prefix_cache").unwrap();
        assert_eq!(pc.get("lookups").unwrap().as_f64(), Some(2.0));
        assert_eq!(pc.get("hits").unwrap().as_f64(), Some(1.0));
        assert!(pc.get("matched_rows").unwrap().as_f64().unwrap() >= 16.0);
        assert!(pc.get("inserted_runs").unwrap().as_f64().unwrap() >= 1.0);
        assert_eq!(pc.get("skipped_contiguous").unwrap().as_f64(), Some(0.0));
        // the cold front-end still answers with the all-zero object
        let cold = request(&b, r#"{"stats": true}"#);
        let pc = cold.get("prefix_cache").unwrap();
        assert_eq!(pc.get("lookups").unwrap().as_f64(), Some(0.0));
    }
}
