//! Byte-level tokenizer shared with the python training pipeline.
//!
//! Token ids 0..=255 are raw bytes; 256 = BOS, 257 = EOS, 258 = PAD.
//! (python/compile/model.py defines the same constants.)

/// Vocabulary size: 256 bytes + BOS + EOS + PAD.
pub const VOCAB: usize = 259;
/// Beginning-of-sequence token id.
pub const BOS: u32 = 256;
/// End-of-sequence token id.
pub const EOS: u32 = 257;
/// Padding token id (also a generation terminator).
pub const PAD: u32 = 258;

/// Encode text as byte tokens.
pub fn encode(text: &str) -> Vec<u32> {
    text.as_bytes().iter().map(|&b| b as u32).collect()
}

/// Decode tokens back to text. Special tokens are dropped; invalid UTF-8 is
/// replaced (generation can split multi-byte characters at block bounds).
pub fn decode(tokens: &[u32]) -> String {
    let bytes: Vec<u8> = tokens
        .iter()
        .filter(|&&t| t < 256)
        .map(|&t| t as u8)
        .collect();
    String::from_utf8_lossy(&bytes).into_owned()
}

/// Is this token a generation terminator?
pub fn is_terminal(token: u32) -> bool {
    token == EOS || token == PAD
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_ascii() {
        let t = encode("hello, world!\n");
        assert_eq!(decode(&t), "hello, world!\n");
        assert!(t.iter().all(|&x| x < 256));
    }

    #[test]
    fn roundtrip_utf8() {
        let s = "héllo ✓";
        assert_eq!(decode(&encode(s)), s);
    }

    #[test]
    fn specials_dropped_on_decode() {
        let mut t = encode("ab");
        t.push(EOS);
        t.push(PAD);
        assert_eq!(decode(&t), "ab");
    }

    #[test]
    fn terminality() {
        assert!(is_terminal(EOS));
        assert!(is_terminal(PAD));
        assert!(!is_terminal(BOS));
        assert!(!is_terminal(65));
    }
}
