//! End-to-end serving-stack validation on the CPU reference backend — the
//! tier-1 proof that the whole draft → tree-verify → verify → commit loop
//! (not just the verification kernels) is lossless and deterministic.
//!
//! Three layers of evidence:
//!
//! 1. **Greedy equality** — at temperature 0 every distribution is a
//!    one-hot, so speculative decoding must reproduce the autoregressive
//!    argmax chain *exactly*, for all eight verifiers. This pins the KV
//!    commit logic: a single mis-committed row would derail the chain.
//! 2. **Monte-Carlo conditionals** — the same validation style as
//!    `losslessness.rs`, but driven through `SpecEngine::step` on a real
//!    backend instead of synthetic trees: the first emitted token of a
//!    block must follow p(.|prompt) exactly, and conditioned on the first
//!    token (when the block is long enough) the second must follow
//!    p(.|prompt, t1), where both conditionals are computed exactly from
//!    the backend itself.
//! 3. **Batch equivalence** — `ServeLoop` token streams are bit-identical
//!    across batch sizes, worker counts *and KV storages* (contiguous vs
//!    paged, the oracle claim of `kvcache::paged`), and identical to
//!    serial `SpecEngine::generate` calls on the same per-request rng
//!    streams.
//! 4. **Block backpressure** — oversubscribing a capped block pool queues
//!    requests instead of failing them, streams stay bit-identical to an
//!    uncapped run, and retiring lanes return every block to the free
//!    list.

mod common;

use std::collections::HashMap;

use common::mc::{check_counts, replay_block_conditionals};
use specdelay::coordinator::{
    generate_autoregressive, FixedPolicy, SchedConfig, ServeLoop, ServeRequest, SpecEngine,
};
use specdelay::dist::{Dist, SamplingConfig};
use specdelay::draft::Action;
use specdelay::kvcache::KvStorage;
use specdelay::runtime::{Backend, CpuModelConfig, CpuRefBackend, Role};
use specdelay::util::Pcg64;
use specdelay::verify::all_verifiers;

/// At temperature 0 both models are deterministic argmax chains, so every
/// lossless verifier must emit exactly the autoregressive target stream
/// (speculation may overshoot the budget by part of a block, so the AR
/// stream is a prefix).
#[test]
fn greedy_spec_equals_autoregressive_all_verifiers() {
    let backend = CpuRefBackend::new(&CpuModelConfig::tiny(), 9);
    let sampling = SamplingConfig::new(0.0, 1.0);
    let prompt = "12*3= ";
    let max_new = 40;
    let mut ar_rng = Pcg64::seeded(1);
    let (ar_text, ar_stats) =
        generate_autoregressive(&backend, sampling, prompt, max_new, &mut ar_rng).unwrap();
    assert_eq!(ar_stats.tokens, max_new, "greedy AR must run to the budget");
    let spec = SpecEngine::new(&backend, sampling);
    for verifier in all_verifiers() {
        let mut rng = Pcg64::seeded(2);
        let policy = FixedPolicy(Action::new(2, 2, 2));
        let (text, stats) =
            spec.generate(prompt, max_new, verifier.as_ref(), &policy, &mut rng).unwrap();
        assert!(stats.tokens >= max_new, "{}: stopped early", verifier.name());
        assert!(
            text.starts_with(&ar_text),
            "{}: greedy stream diverged\n  ar:   {ar_text:?}\n  spec: {text:?}",
            verifier.name()
        );
    }
}

/// Monte-Carlo e2e losslessness: replay one speculation block many times
/// from the same prefilled sequence and check the emitted-stream
/// conditionals against the backend's exact target conditionals (shared
/// seeded-sampling machinery in `common::mc`).
#[test]
fn e2e_block_conditionals_follow_target_all_verifiers() {
    let backend = CpuRefBackend::new(&CpuModelConfig::tiny(), 3);
    let sampling = SamplingConfig::new(0.5, 0.9);
    let spec = SpecEngine::new(&backend, sampling);
    let prompt = "7+5= ";
    let base = spec.start(prompt).unwrap();
    let v = backend.dims(Role::Target).vocab;

    // exact first-token conditional p(.|prompt) from a target prefill
    let toks_i32: Vec<i32> = base.tokens.iter().map(|&t| t as i32).collect();
    let pre = backend.prefill(Role::Target, &toks_i32, base.prompt_len).unwrap();
    let p0 = Dist::from_logits(&pre.logits, sampling);

    // exact second-token conditionals p(.|prompt, t1), computed lazily
    let mut cond: HashMap<u32, Dist> = HashMap::new();

    let n = common::mc::mc_samples(1200);
    for (vi, verifier) in all_verifiers().into_iter().enumerate() {
        let tallies = replay_block_conditionals(
            &spec,
            &base,
            verifier.as_ref(),
            Action::new(2, 1, 1),
            v,
            n,
            0xE2E + vi as u64,
        );
        check_counts(
            &format!("{} first-token", verifier.name()),
            &tallies.first,
            &p0.0,
            n,
            0.005,
        );
        for (t1, c) in &tallies.second {
            let total: usize = c.iter().sum();
            if total < 350 {
                continue; // not enough conditional mass to test tightly
            }
            let p1 = cond.entry(*t1).or_insert_with(|| {
                // context = prompt + t1: decode t1 at the next position over
                // the prompt-prefilled cache
                let d = backend
                    .decode(Role::Target, base.target_kv.view(), *t1, base.prompt_len)
                    .unwrap();
                Dist::from_logits(&d.logits, sampling)
            });
            check_counts(
                &format!("{} second-token|{t1}", verifier.name()),
                c,
                &p1.0,
                total,
                0.005,
            );
        }
    }
}

/// Per-request token streams must be bit-identical for every batch size,
/// worker count and KV storage (the paged cache is a bit-exact drop-in
/// for the contiguous oracle), and identical to serial generation on the
/// same per-request rng stream (`Pcg64::new(seed, id)`).
#[test]
fn batched_serving_matches_serial_generate() {
    let backend = CpuRefBackend::new(&CpuModelConfig::tiny(), 4);
    let sampling = SamplingConfig::new(0.8, 0.95);
    let verifier = specdelay::verify::verifier("SpecInfer").unwrap();
    let policy = FixedPolicy(Action::new(2, 2, 2));
    let prompts = ["12*3= ", "9-4= ", "1,2,3,", "(5+5)/2= ", "0.5*8= ", "77+1= "];
    let max_new = 24;

    // serial reference on contiguous storage — the oracle for everything
    let spec =
        SpecEngine::new(&backend, sampling).with_kv_storage(KvStorage::Contiguous);
    let mut reference = Vec::new();
    for (id, p) in prompts.iter().enumerate() {
        let mut rng = Pcg64::new(1234, id as u64);
        let (text, stats) =
            spec.generate(p, max_new, verifier.as_ref(), &policy, &mut rng).unwrap();
        reference.push((text, stats.tokens, stats.blocks));
    }

    for storage in [KvStorage::Contiguous, KvStorage::Paged] {
        for batch in [1usize, 3, 8] {
            for workers in [1usize, 4] {
                let mut srv =
                    ServeLoop::new(&backend, sampling, verifier.as_ref(), &policy, batch)
                        .with_workers(workers)
                        .with_kv_storage(storage);
                for p in &prompts {
                    srv.submit(ServeRequest::new(p.to_string(), max_new, 1234));
                }
                let outs = srv.run().unwrap();
                assert_eq!(outs.len(), prompts.len());
                for (o, (text, tokens, blocks)) in outs.iter().zip(&reference) {
                    assert!(o.error.is_none(), "lane {} failed: {:?}", o.id, o.error);
                    assert_eq!(
                        &o.text, text,
                        "stream diverged: storage {storage:?} batch {batch} workers {workers} id {}",
                        o.id
                    );
                    assert_eq!(o.stats.tokens, *tokens);
                    assert_eq!(o.stats.blocks, *blocks);
                }
                // every paged lane retired: its blocks are all back in the
                // free list, none live (under SPECDELAY_PREFIX_CACHE=1 the
                // cache legitimately retains runs — flush it first)
                srv.clear_prefix_cache();
                if let Some(pools) = srv.spec().kv_pools() {
                    for (role, pool) in
                        [("target", &pools.target), ("draft", &pools.draft)]
                    {
                        pool.validate().unwrap();
                        assert_eq!(
                            pool.live_blocks(),
                            0,
                            "{role} pool leaked blocks (batch {batch} workers {workers})"
                        );
                    }
                }
            }
        }
    }
}

/// The cross-request prefix cache is a pure latency optimisation: warm
/// streams must stay bit-identical to the cold serial oracle across
/// storages, batch sizes, worker counts and both admission modes. The
/// prompts share a template prefix spanning whole KV blocks, so repeat
/// admissions deterministically hit the cache whenever any retirement
/// precedes an admission (batch < number of prompts). Also pins the
/// satellite contracts: `cached_prefix_rows` plumbing, the
/// `skipped_contiguous` fallback, counter accounting
/// (`lookups == hits + misses`, `matched_rows == Σ cached_prefix_rows`)
/// and zero leaked blocks once the loop — cache included — is dropped.
#[test]
fn prefix_cached_serving_is_bit_identical_to_cold() {
    let backend = CpuRefBackend::new(&CpuModelConfig::tiny(), 4);
    let sampling = SamplingConfig::new(0.8, 0.95);
    let verifier = specdelay::verify::verifier("SpecInfer").unwrap();
    let policy = FixedPolicy(Action::new(2, 2, 2));
    // 48-char template + BOS = 49 shared tokens = 3 whole blocks of 16
    let template = "sum table: 1+1=2; 2+2=4; 3+3=6; 4+4=8; 5+5=10;  ";
    assert_eq!(template.len(), 48);
    let prompts: Vec<String> = ["12*3= ", "9-4= ", "1,2,3,", "(5+5)/2= ", "0.5*8= ", "77+1= "]
        .iter()
        .map(|p| format!("{template}{p}"))
        .collect();
    let max_new = 24;

    // serial reference on contiguous storage, cache never in play
    let spec = SpecEngine::new(&backend, sampling).with_kv_storage(KvStorage::Contiguous);
    let mut reference = Vec::new();
    for (id, p) in prompts.iter().enumerate() {
        let mut rng = Pcg64::new(1234, id as u64);
        let (text, _stats) =
            spec.generate(p, max_new, verifier.as_ref(), &policy, &mut rng).unwrap();
        reference.push(text);
    }

    for sched in [false, true] {
        for storage in [KvStorage::Contiguous, KvStorage::Paged] {
            for batch in [1usize, 3, 8] {
                for workers in [1usize, 4] {
                    let ctx = format!(
                        "sched {sched} storage {storage:?} batch {batch} workers {workers}"
                    );
                    let mut srv =
                        ServeLoop::new(&backend, sampling, verifier.as_ref(), &policy, batch)
                            .with_workers(workers)
                            .with_kv_storage(storage)
                            .with_prefix_cache(true);
                    srv = if sched {
                        srv.with_scheduler(SchedConfig {
                            prefill_chunk: 4,
                            ..SchedConfig::default()
                        })
                    } else {
                        srv.without_scheduler()
                    };
                    for p in &prompts {
                        srv.submit(ServeRequest::new(p.clone(), max_new, 1234));
                    }
                    let outs = srv.run().unwrap();
                    assert_eq!(outs.len(), prompts.len());
                    let mut cached_total = 0usize;
                    for (o, text) in outs.iter().zip(&reference) {
                        assert!(o.error.is_none(), "lane {} failed ({ctx}): {:?}", o.id, o.error);
                        assert_eq!(&o.text, text, "warm stream diverged ({ctx}, id {})", o.id);
                        cached_total += o.cached_prefix_rows;
                    }
                    let c = srv.prefix_counters();
                    match storage {
                        KvStorage::Contiguous => {
                            // graceful fallback: every admission counted,
                            // nothing looked up, nothing adopted
                            assert_eq!(cached_total, 0, "{ctx}");
                            assert_eq!(c.lookups, 0, "{ctx}");
                            assert_eq!(c.skipped_contiguous, prompts.len() as u64, "{ctx}");
                        }
                        KvStorage::Paged => {
                            assert_eq!(c.lookups, prompts.len() as u64, "{ctx}");
                            assert_eq!(c.skipped_contiguous, 0, "{ctx}");
                            assert!(c.hits <= c.lookups, "{ctx}");
                            let misses = c.lookups - c.hits;
                            assert_eq!(c.hits + misses, c.lookups, "{ctx}");
                            assert_eq!(
                                c.matched_rows, cached_total as u64,
                                "adopted rows must all be attributed ({ctx})"
                            );
                            if batch < prompts.len() {
                                // some admission follows a retirement, so a
                                // hit on the 3-block template is guaranteed
                                assert!(c.hits > 0, "{ctx}");
                                assert!(cached_total >= 48, "{ctx}: cached {cached_total}");
                            } else {
                                // every request admitted before any insert
                                assert_eq!(c.hits, 0, "{ctx}");
                                assert_eq!(cached_total, 0, "{ctx}");
                            }
                            assert!(c.inserted_runs >= 1, "{ctx}");
                        }
                    }
                    // cached blocks are live while the cache holds them;
                    // dropping the loop (and with it the cache) must hand
                    // every block back
                    if let Some(pools) = srv.spec().kv_pools() {
                        pools.target.validate().unwrap();
                        pools.draft.validate().unwrap();
                        let keep = (pools.target.clone(), pools.draft.clone());
                        drop(srv);
                        for (role, pool) in [("target", &keep.0), ("draft", &keep.1)] {
                            pool.validate().unwrap();
                            assert_eq!(
                                pool.live_blocks(),
                                0,
                                "{role} pool leaked blocks after cache drop ({ctx})"
                            );
                        }
                    }
                }
            }
        }
    }
}

/// Out-of-blocks backpressure: many lanes against a deliberately tiny
/// block pool. Requests must queue (never fail), every stream must be
/// bit-identical to an uncapped run, the pool cap must be respected at its
/// high-water mark, and lane retirement must return every block.
#[test]
fn serve_loop_block_backpressure_queues_and_completes() {
    let backend = CpuRefBackend::new(&CpuModelConfig::tiny(), 4);
    let sampling = SamplingConfig::new(0.8, 0.95);
    let verifier = specdelay::verify::verifier("Traversal").unwrap();
    let policy = FixedPolicy(Action::new(2, 2, 2));
    let prompts = ["12*3= ", "9-4= ", "1,2,3,", "(5+5)/2= ", "0.5*8= ", "77+1= ", "6/2= "];
    let max_new = 16;

    // uncapped paged run: the equality oracle
    let mut free = ServeLoop::new(&backend, sampling, verifier.as_ref(), &policy, 8)
        .with_kv_storage(KvStorage::Paged);
    for p in &prompts {
        free.submit(ServeRequest::new(p.to_string(), max_new, 99));
    }
    let want: Vec<String> = free.run().unwrap().into_iter().map(|o| o.text).collect();

    // capped run: budget 1 forces the smallest pool that still fits one
    // lane (the cap is clamped to the per-lane reserve), so with 8 batch
    // slots the block budget — not max_batch — is what serialises lanes
    let mut srv = ServeLoop::new(&backend, sampling, verifier.as_ref(), &policy, 8)
        .with_block_budget(1);
    for p in &prompts {
        srv.submit(ServeRequest::new(p.to_string(), max_new, 99));
    }
    assert_eq!(srv.queued(), prompts.len());
    let outs = srv.run().unwrap();
    assert_eq!(srv.queued(), 0, "every queued request must be served");
    assert_eq!(outs.len(), prompts.len());
    for (o, want_text) in outs.iter().zip(&want) {
        assert!(o.error.is_none(), "lane {} failed under backpressure: {:?}", o.id, o.error);
        assert_eq!(&o.text, want_text, "capped stream diverged (id {})", o.id);
    }
    srv.clear_prefix_cache(); // cache-held runs are not leaks
    let pools = srv.spec().kv_pools().expect("block budget implies paged pools");
    for (role, pool) in [("target", &pools.target), ("draft", &pools.draft)] {
        pool.validate().unwrap();
        let cap = pool.max_blocks().unwrap();
        assert!(
            pool.peak_live_blocks() <= cap,
            "{role} pool exceeded its cap: peak {} > {cap}",
            pool.peak_live_blocks()
        );
        assert_eq!(pool.live_blocks(), 0, "{role} pool: lane retirement leaked blocks");
        assert_eq!(
            pool.free_blocks(),
            pool.created(),
            "{role} pool: free list must hold every created block after the drain"
        );
    }
}

/// Incremental-KV completeness: after any number of blocks, every draft
/// cache row the next block will attend (positions `< root_pos`) must
/// equal the row a from-scratch prefill of the same context computes —
/// bitwise, by the backend's consistency contract. This is the invariant
/// that catches a missing deepest-accepted-row commit: rollouts only
/// carry rows for visited nodes, so fully accepted chains need the
/// back-fill decode in `SpecEngine::commit`.
#[test]
fn draft_cache_rows_match_from_scratch_prefill() {
    let sampling = SamplingConfig::new(0.0, 1.0); // greedy maximizes full acceptance
    for model_seed in 0..5u64 {
        let backend = CpuRefBackend::new(&CpuModelConfig::tiny(), model_seed);
        // the invariant must hold for both storages (the paged cache
        // back-fills through the same page-mapped commit path)
        let storage = if model_seed % 2 == 0 { KvStorage::Contiguous } else { KvStorage::Paged };
        let spec = SpecEngine::new(&backend, sampling).with_kv_storage(storage);
        let verifier = specdelay::verify::verifier("SpecInfer").unwrap();
        for action in [Action::new(1, 2, 0), Action::new(2, 1, 1)] {
            let mut seq = spec.start("12*3= ").unwrap();
            let mut rng = Pcg64::new(77 + model_seed, action.k as u64);
            for _ in 0..4 {
                spec.step(&mut seq, verifier.as_ref(), action, &mut rng).unwrap();
            }
            let n = seq.root_pos; // rows < root_pos are required-valid
            assert!(n <= backend.meta().s_pre, "context outgrew prefill capacity");
            let toks: Vec<i32> = seq.tokens.iter().take(n).map(|&t| t as i32).collect();
            let pre = backend.prefill(Role::Draft, &toks, n).unwrap();
            let dims = backend.dims(Role::Draft);
            let s_pre = backend.meta().s_pre;
            for l in 0..dims.n_layers {
                for hh in 0..dims.n_heads {
                    for p in 0..n {
                        let src = ((l * dims.n_heads + hh) * s_pre + p) * dims.d_head;
                        let (krow, vrow) = seq.draft_kv.read_row(l, hh, p);
                        assert_eq!(
                            &pre.k_rows[src..src + dims.d_head],
                            krow,
                            "stale draft K row: seed {model_seed} storage {storage:?} action {action:?} l={l} h={hh} pos={p}"
                        );
                        assert_eq!(
                            &pre.v_rows[src..src + dims.d_head],
                            vrow,
                            "stale draft V row: seed {model_seed} storage {storage:?} action {action:?} l={l} h={hh} pos={p}"
                        );
                    }
                }
            }
        }
    }
}

/// The scheduler keeps the batch full from the queue: more requests than
/// slots retire in id order with every request served.
#[test]
fn serve_loop_drains_queue_in_order() {
    let backend = CpuRefBackend::new(&CpuModelConfig::tiny(), 5);
    let sampling = SamplingConfig::new(0.7, 1.0);
    let verifier = specdelay::verify::verifier("Traversal").unwrap();
    let policy = FixedPolicy(Action::new(3, 1, 2));
    let mut srv = ServeLoop::new(&backend, sampling, verifier.as_ref(), &policy, 2);
    let n = 5usize;
    for i in 0..n {
        // staggered lengths force mid-run admission
        let id = srv.submit(ServeRequest::new(format!("{i}+{i}= "), 8 + 4 * i, 7));
        assert_eq!(id, i as u64);
    }
    assert_eq!(srv.queued(), n);
    let outs = srv.run().unwrap();
    assert_eq!(srv.queued(), 0);
    assert_eq!(outs.len(), n);
    for (i, o) in outs.iter().enumerate() {
        assert_eq!(o.id, i as u64);
        assert!(o.error.is_none(), "request {i} failed: {:?}", o.error);
        assert!(o.stats.tokens >= 8 + 4 * i, "request {i} under budget");
        assert!(o.stats.blocks > 0);
    }
}
