//! Serving-time online-selector validation: the dynamic
//! (verifier × drafter × action) policy wired into `ServeLoop` must keep
//! every determinism contract the static path has, and its online
//! calibration must be worker-count independent.
//!
//! * **Oracle equality** — selector-driven `ServeLoop` streams are
//!   bit-identical across batch sizes, worker counts, KV storages and
//!   FIFO/scheduler modes, and identical to a serial replay of the same
//!   per-request rng streams (`Pcg64::new(seed, id)` for tokens,
//!   `Pcg64::new(selector seed, id)` for decisions).
//! * **Calibration determinism** — per-arm acceptance priors folded from
//!   served traffic equal the serial tallies for every worker count.
//! * **Transparency** — a selector with no arms (the `SPECDELAY_SELECTOR=1`
//!   default config) serves byte-for-byte the legacy static path.
//! * **Rng decoupling** — drafter/selector decisions draw from their own
//!   stream: changing only the selector seed never perturbs token streams
//!   (the regression for the rng-stream coupling hazard).

use specdelay::coordinator::{FixedPolicy, SchedConfig, ServeLoop, ServeRequest, SpecEngine};
use specdelay::dist::SamplingConfig;
use specdelay::draft::{Action, DrafterKind};
use specdelay::kvcache::KvStorage;
use specdelay::runtime::{CpuModelConfig, CpuRefBackend};
use specdelay::selector::{ArmStats, OnlineSelector, SelectorArm, SelectorConfig};
use specdelay::tokenizer;
use specdelay::util::Pcg64;

const PROMPTS: [&str; 6] = ["12*3= ", "9-4= ", "1,2,3,", "(5+5)/2= ", "0.5*8= ", "77+1= "];
const MAX_NEW: usize = 20;
const SEED: u64 = 1234;

/// An arm set spanning all three drafters and two verifiers.
fn arms() -> Vec<SelectorArm> {
    let arm = |verifier: &str, drafter, k, l1, l2| SelectorArm {
        verifier: verifier.to_string(),
        drafter,
        action: Action::new(k, l1, l2),
    };
    vec![
        arm("SpecInfer", DrafterKind::Delayed, 2, 2, 2),
        arm("Traversal", DrafterKind::Root, 3, 0, 2),
        arm("SpecInfer", DrafterKind::Greedy, 2, 2, 2),
        arm("Traversal", DrafterKind::Delayed, 1, 4, 0),
    ]
}

fn cfg(epsilon: f32, seed: u64) -> SelectorConfig {
    SelectorConfig { arms: arms(), seed, epsilon, ..SelectorConfig::default() }
}

/// Serial replay of one selector-driven lane through the public API —
/// the oracle every `ServeLoop` configuration must match bit-for-bit.
/// Returns the decoded stream and the per-arm acceptance tallies.
fn serial_selector_oracle(
    backend: &CpuRefBackend,
    sampling: SamplingConfig,
    config: &SelectorConfig,
    storage: KvStorage,
    prompt: &str,
    id: u64,
) -> (String, Vec<ArmStats>) {
    let sel = OnlineSelector::new(config.clone()).unwrap();
    let spec = SpecEngine::new(backend, sampling).with_kv_storage(storage);
    let mut seq = spec.start(prompt).unwrap();
    let mut rng = Pcg64::new(SEED, id);
    let mut sel_rng = Pcg64::new(config.seed, id);
    let mut tally = vec![ArmStats::default(); config.arms.len()];
    while !seq.finished && seq.tokens.len() - seq.prompt_len < MAX_NEW {
        let i = {
            let f = spec.root_features(&mut seq).unwrap();
            let feats = f.as_features(&seq, sampling);
            sel.choose(&feats, &mut sel_rng).unwrap()
        };
        let arm = &sel.arms()[i];
        let b = spec
            .step_drafted(&mut seq, sel.verifier(i), arm.action, arm.drafter, &mut rng)
            .unwrap();
        tally[i].record(b.tree_nodes.saturating_sub(1), b.accepted, b.emitted);
    }
    (tokenizer::decode(&seq.tokens[seq.prompt_len..]), tally)
}

/// Selector-driven streams are bit-identical across batch {1,3,8} ×
/// workers {1,4} × both KV storages × FIFO/scheduler modes, and equal to
/// the serial oracle; the online-calibrated priors equal the summed
/// serial tallies in every configuration (so they are independent of
/// batching, workers, storage and scheduling — not just worker count).
#[test]
fn selector_streams_and_priors_match_serial_oracle() {
    let backend = CpuRefBackend::new(&CpuModelConfig::tiny(), 4);
    let sampling = SamplingConfig::new(0.8, 0.95);
    // static fallbacks the selector path must never consult
    let verifier = specdelay::verify::verifier("BV").unwrap();
    let policy = FixedPolicy(Action::new(1, 1, 0));
    let config = cfg(0.25, 0x5e1ec7);

    for storage in [KvStorage::Contiguous, KvStorage::Paged] {
        // oracle per request id + accumulated expected priors
        let mut reference = Vec::new();
        let mut want_priors = vec![ArmStats::default(); config.arms.len()];
        for (id, p) in PROMPTS.iter().enumerate() {
            let (text, tally) =
                serial_selector_oracle(&backend, sampling, &config, storage, p, id as u64);
            for (w, t) in want_priors.iter_mut().zip(&tally) {
                w.merge(t);
            }
            reference.push(text);
        }
        assert!(
            want_priors.iter().map(|a| a.blocks).sum::<u64>() > 0,
            "oracle served no selector blocks"
        );

        for sched in [false, true] {
            for batch in [1usize, 3, 8] {
                for workers in [1usize, 4] {
                    let ctx =
                        format!("storage {storage:?} sched {sched} batch {batch} workers {workers}");
                    let mut srv =
                        ServeLoop::new(&backend, sampling, verifier.as_ref(), &policy, batch)
                            .with_workers(workers)
                            .with_kv_storage(storage)
                            .with_selector(config.clone());
                    srv = if sched {
                        srv.with_scheduler(SchedConfig {
                            prefill_chunk: 4,
                            ..SchedConfig::default()
                        })
                    } else {
                        srv.without_scheduler()
                    };
                    assert!(srv.selector_active());
                    for p in &PROMPTS {
                        srv.submit(ServeRequest::new(p.to_string(), MAX_NEW, SEED));
                    }
                    let outs = srv.run().unwrap();
                    assert_eq!(outs.len(), PROMPTS.len());
                    for (o, text) in outs.iter().zip(&reference) {
                        assert!(o.error.is_none(), "lane {} failed ({ctx}): {:?}", o.id, o.error);
                        assert_eq!(&o.text, text, "selector stream diverged ({ctx}, id {})", o.id);
                    }
                    assert_eq!(
                        srv.selector_priors().arms,
                        want_priors,
                        "calibrated priors diverged from the serial tallies ({ctx})"
                    );
                    // every selector block is accounted into exactly one arm
                    let blocks: u64 = srv.selector_priors().arms.iter().map(|a| a.blocks).sum();
                    let served: u64 = outs.iter().map(|o| o.stats.blocks as u64).sum();
                    assert_eq!(blocks, served, "{ctx}");
                }
            }
        }
    }
}

/// The explicit worker-count determinism property for the calibration
/// fold: identical priors for 1 and 4 workers, and non-trivial traffic on
/// the arm set (the fold actually ran).
#[test]
fn selector_calibration_priors_worker_count_independent() {
    let backend = CpuRefBackend::new(&CpuModelConfig::tiny(), 7);
    let sampling = SamplingConfig::new(0.7, 1.0);
    let verifier = specdelay::verify::verifier("BV").unwrap();
    let policy = FixedPolicy(Action::new(1, 1, 0));
    let config = cfg(0.25, 9);

    let mut priors = Vec::new();
    for workers in [1usize, 4] {
        let mut srv = ServeLoop::new(&backend, sampling, verifier.as_ref(), &policy, 4)
            .with_workers(workers)
            .with_selector(config.clone());
        for p in &PROMPTS {
            srv.submit(ServeRequest::new(p.to_string(), MAX_NEW, SEED));
        }
        let outs = srv.run().unwrap();
        assert!(outs.iter().all(|o| o.error.is_none()));
        priors.push(srv.selector_priors().clone());
    }
    assert_eq!(priors[0], priors[1], "priors depend on the worker count");
    let total: u64 = priors[0].arms.iter().map(|a| a.blocks).sum();
    assert!(total > 0, "no selector traffic was calibrated");
    assert!(
        priors[0].arms.iter().map(|a| a.drafted).sum::<u64>() > 0,
        "no draft tokens tallied"
    );
}

/// A selector configured with no arms (the `SPECDELAY_SELECTOR=1` default)
/// is engaged but transparent: streams, stats and block counts are
/// byte-for-byte the legacy static path, and nothing is calibrated.
#[test]
fn selector_empty_config_is_legacy_byte_for_byte() {
    let backend = CpuRefBackend::new(&CpuModelConfig::tiny(), 4);
    let sampling = SamplingConfig::new(0.8, 0.95);
    let verifier = specdelay::verify::verifier("SpecInfer").unwrap();
    let policy = FixedPolicy(Action::new(2, 2, 2));

    let run = |selector: bool| -> Vec<(String, usize, usize)> {
        let mut srv = ServeLoop::new(&backend, sampling, verifier.as_ref(), &policy, 3)
            .with_workers(2);
        if selector {
            srv = srv.with_selector(SelectorConfig::default());
            assert!(srv.selector().is_some());
            assert!(!srv.selector_active(), "empty config must stay transparent");
        }
        for p in &PROMPTS {
            srv.submit(ServeRequest::new(p.to_string(), MAX_NEW, SEED));
        }
        let outs = srv.run().unwrap();
        assert!(srv.selector_priors().arms.iter().all(|a| a.blocks == 0));
        outs.iter()
            .map(|o| {
                assert!(o.error.is_none());
                (o.text.clone(), o.stats.tokens, o.stats.blocks)
            })
            .collect()
    };
    assert_eq!(run(false), run(true), "engaged-but-armless selector changed the stream");
}

/// The rng-decoupling regression: with a single arm every decision is
/// forced, so *only* the selector seed (and its exploration draws) change
/// between runs — token streams must be bit-identical, and equal to the
/// equivalent static run (same verifier/drafter/action via `FixedPolicy`
/// + `with_drafter`).
#[test]
fn selector_seed_change_never_perturbs_token_streams() {
    let backend = CpuRefBackend::new(&CpuModelConfig::tiny(), 4);
    let sampling = SamplingConfig::new(0.8, 0.95);
    let fallback = specdelay::verify::verifier("BV").unwrap();
    let fallback_policy = FixedPolicy(Action::new(1, 1, 0));
    let arm = SelectorArm {
        verifier: "Traversal".to_string(),
        drafter: DrafterKind::Greedy,
        action: Action::new(2, 2, 2),
    };

    let run = |sel_seed: u64| -> Vec<String> {
        let config = SelectorConfig {
            arms: vec![arm.clone()],
            seed: sel_seed,
            epsilon: 0.5, // exploration draws differ per seed; the arm cannot change
            ..SelectorConfig::default()
        };
        let mut srv = ServeLoop::new(&backend, sampling, fallback.as_ref(), &fallback_policy, 3)
            .with_workers(2)
            .with_selector(config);
        for p in &PROMPTS {
            srv.submit(ServeRequest::new(p.to_string(), MAX_NEW, SEED));
        }
        srv.run().unwrap().into_iter().map(|o| o.text).collect()
    };
    let a = run(0xAA);
    let b = run(0xBB);
    assert_eq!(a, b, "selector seed leaked into token sampling rng");

    // single-arm selector ≡ the static configuration it pins
    let verifier = specdelay::verify::verifier("Traversal").unwrap();
    let policy = FixedPolicy(arm.action);
    let spec = SpecEngine::new(&backend, sampling).with_drafter(arm.drafter);
    for (id, (p, got)) in PROMPTS.iter().zip(&a).enumerate() {
        let mut rng = Pcg64::new(SEED, id as u64);
        let (text, _) =
            spec.generate(p, MAX_NEW, verifier.as_ref(), &policy, &mut rng).unwrap();
        assert_eq!(&text, got, "single-arm selector diverged from static (id {id})");
    }
}
