//! Integration contract of the f32x8 SIMD backend and the quantized KV
//! pools.
//!
//! Three layers of pinning on top of the in-module unit tests:
//!
//! * **Odd shapes** — the ≤ 1e-5 relative per-op tolerance of
//!   `cpu-simd` against `cpu-ref` must hold when every reduction length
//!   has a scalar tail (`d_head` not a multiple of 8, odd head counts,
//!   odd vocab), including single-row prefills and chunked prefills at
//!   non-aligned offsets.
//! * **Sequence-capacity edge** — both backends must agree (within
//!   tolerance) all the way to `max_seq - 1` and reject `max_seq`
//!   identically.
//! * **End-to-end greedy divergence bound** — teacher-forcing the scalar
//!   reference's greedy stream through every (backend × kv-dtype) cell,
//!   each cell's per-step argmax must match the reference wherever the
//!   reference's top-2 logit gap exceeds the cell's error budget (f32:
//!   rounding, f16: half-precision KV, int8: affine-code KV). The
//!   bit-exact rung — `cpu-ref` over f32 paged storage — must agree at
//!   *every* step with no margin at all.

use specdelay::kvcache::{BlockPool, KvCache, KvDtype};
use specdelay::runtime::{Backend, CpuModelConfig, CpuRefBackend, CpuSimdBackend, Role};
use specdelay::tree::{DraftTree, Provenance};

/// Max relative error of `got` against `want` (absolute floor 1e-6 so
/// near-zero entries compare sanely).
fn rel_err(got: &[f32], want: &[f32]) -> f32 {
    assert_eq!(got.len(), want.len());
    got.iter()
        .zip(want)
        .map(|(&g, &w)| (g - w).abs() / w.abs().max(1e-6))
        .fold(0.0f32, f32::max)
}

const TOL: f32 = 1e-5;

/// Shapes chosen so every lane-chunked reduction has a non-empty scalar
/// tail: `d_head` 10 (even for RoPE, not a multiple of 8), 3 heads
/// (`d_attn` 30), `d_model` 22, `d_mlp` 44, vocab 83.
fn odd_config() -> CpuModelConfig {
    CpuModelConfig {
        n_layers: 2,
        d_model: 22,
        n_heads: 3,
        d_head: 10,
        vocab: 83,
        max_seq: 40,
        s_pre: 21,
        mlp_ratio: 2,
        logit_scale: 30.0,
    }
}

#[test]
fn simd_within_tolerance_on_odd_shapes_all_entry_points() {
    let cfg = odd_config();
    let rb = CpuRefBackend::new(&cfg, 17);
    let sb = CpuSimdBackend::new(&cfg, 17);
    let toks: Vec<i32> = (0..13).map(|i| (i * 29 + 7) % 83).collect();

    for role in [Role::Target, Role::Draft] {
        // single-row prefill: the smallest batch, tails everywhere
        let pr1 = rb.prefill(role, &toks[..1], 1).unwrap();
        let ps1 = sb.prefill(role, &toks[..1], 1).unwrap();
        assert!(rel_err(&ps1.logits, &pr1.logits) <= TOL, "{role:?} len-1 prefill logits");
        assert!(rel_err(&ps1.hidden, &pr1.hidden) <= TOL, "{role:?} len-1 prefill hidden");

        // full odd-length prefill
        let pr = rb.prefill(role, &toks, toks.len()).unwrap();
        let ps = sb.prefill(role, &toks, toks.len()).unwrap();
        assert!(rel_err(&ps.logits, &pr.logits) <= TOL, "{role:?} prefill logits");
        assert!(rel_err(&ps.k_rows, &pr.k_rows) <= TOL, "{role:?} prefill k_rows");
        assert!(rel_err(&ps.v_rows, &pr.v_rows) <= TOL, "{role:?} prefill v_rows");

        // chunked prefill at non-aligned offsets, each backend reading its
        // own committed rows
        let mut cr = KvCache::new(rb.dims(role));
        let mut cs = KvCache::new(sb.dims(role));
        for (start, len) in [(0usize, 5usize), (5, 2), (7, 6)] {
            let or = rb.prefill_chunk(role, cr.view(), &toks, start, len).unwrap();
            let os = sb.prefill_chunk(role, cs.view(), &toks, start, len).unwrap();
            assert!(
                rel_err(&os.logits, &or.logits) <= TOL,
                "{role:?} chunk {start}+{len} logits"
            );
            assert!(
                rel_err(&os.k_rows, &or.k_rows) <= TOL,
                "{role:?} chunk {start}+{len} k_rows"
            );
            cr.commit_chunk(&or.k_rows, &or.v_rows, len, start, len);
            cs.commit_chunk(&os.k_rows, &os.v_rows, len, start, len);
        }

        // decode over the chunk-built caches
        let dr = rb.decode(role, cr.view(), 19, toks.len()).unwrap();
        let ds = sb.decode(role, cs.view(), 19, toks.len()).unwrap();
        assert!(rel_err(&ds.logits, &dr.logits) <= TOL, "{role:?} decode logits");
        assert!(rel_err(&ds.k_row, &dr.k_row) <= TOL, "{role:?} decode k_row");
        assert!(rel_err(&ds.hidden, &dr.hidden) <= TOL, "{role:?} decode hidden");
    }

    // draft rollout with odd K/L: per-step kept-mass tolerance while the
    // token prefix agrees (a boundary draw legitimately forks the branch)
    let pr = rb.prefill(Role::Draft, &toks, toks.len()).unwrap();
    let ps = sb.prefill(Role::Draft, &toks, toks.len()).unwrap();
    let mut cr = KvCache::new(rb.dims(Role::Draft));
    let mut cs = KvCache::new(sb.dims(Role::Draft));
    cr.commit_prefill(&pr.k_rows, &pr.v_rows, cfg.s_pre, toks.len());
    cs.commit_prefill(&ps.k_rows, &ps.v_rows, cfg.s_pre, toks.len());
    let uni: Vec<f32> = (0..9).map(|i| (i as f32 * 0.107 + 0.03) % 1.0).collect();
    let root = toks[toks.len() - 1] as u32;
    let rr = rb.rollout(3, 3, cr.view(), root, toks.len(), &uni, 0.8, 0.9).unwrap();
    let rs = sb.rollout(3, 3, cs.view(), root, toks.len(), &uni, 0.8, 0.9).unwrap();
    let v = cfg.vocab;
    for b in 0..3usize {
        for j in 0..3usize {
            let slot = b * 3 + j;
            for (a, s) in
                rr.dists[slot * v..(slot + 1) * v].iter().zip(&rs.dists[slot * v..(slot + 1) * v])
            {
                if *a > 0.0 && *s > 0.0 {
                    assert!(
                        (a - s).abs() / a.max(1e-6) <= 1e-4,
                        "rollout b={b} j={j} dist entry {a} vs {s}"
                    );
                }
            }
            if rr.tokens[slot] != rs.tokens[slot] {
                break;
            }
        }
    }

    // target tree pass over a 5-node tree in an 8-bucket (padded lanes)
    let pr = rb.prefill(Role::Target, &toks, toks.len()).unwrap();
    let ps = sb.prefill(Role::Target, &toks, toks.len()).unwrap();
    let mut cr = KvCache::new(rb.dims(Role::Target));
    let mut cs = KvCache::new(sb.dims(Role::Target));
    cr.commit_prefill(&pr.k_rows, &pr.v_rows, cfg.s_pre, toks.len());
    cs.commit_prefill(&ps.k_rows, &ps.v_rows, cfg.s_pre, toks.len());
    let root_pos = toks.len() - 1;
    let mut tree = DraftTree::new(root);
    let a = tree.add_child(0, 12, Provenance::Trunk { step: 1 });
    let _ = tree.add_child(a, 44, Provenance::Branch { branch: 0, step: 0 });
    let _ = tree.add_child(a, 51, Provenance::Branch { branch: 1, step: 0 });
    let _ = tree.add_child(0, 23, Provenance::Trunk { step: 1 });
    let nb = 8;
    let (tt, tp) = tree.tokens_positions(nb, root_pos, 80);
    let bias = tree.attention_bias(nb);
    let tr = rb.tree_verify(nb, cr.view(), &tt, &tp, &bias, root_pos).unwrap();
    let ts = sb.tree_verify(nb, cs.view(), &tt, &tp, &bias, root_pos).unwrap();
    // compare only the real nodes: padding lanes are computed-and-discarded
    for i in 0..tree.len() {
        assert!(
            rel_err(&ts.logits[i * v..(i + 1) * v], &tr.logits[i * v..(i + 1) * v]) <= TOL,
            "tree node {i} logits"
        );
    }
}

/// Both backends must agree within tolerance all the way to the last
/// legal position and reject `max_seq` identically.
#[test]
fn simd_agrees_with_ref_to_the_max_seq_edge() {
    let cfg = CpuModelConfig {
        n_layers: 1,
        d_model: 10,
        n_heads: 1,
        d_head: 10,
        vocab: 37,
        max_seq: 12,
        s_pre: 8,
        mlp_ratio: 2,
        logit_scale: 30.0,
    };
    let rb = CpuRefBackend::new(&cfg, 5);
    let sb = CpuSimdBackend::new(&cfg, 5);
    let toks = [3i32, 11, 7, 19, 2];
    let pr = rb.prefill(Role::Target, &toks, toks.len()).unwrap();
    let ps = sb.prefill(Role::Target, &toks, toks.len()).unwrap();
    let mut cr = KvCache::new(rb.dims(Role::Target));
    let mut cs = KvCache::new(sb.dims(Role::Target));
    cr.commit_prefill(&pr.k_rows, &pr.v_rows, cfg.s_pre, toks.len());
    cs.commit_prefill(&ps.k_rows, &ps.v_rows, cfg.s_pre, toks.len());
    let mut cur = 9u32;
    for pos in toks.len()..cfg.max_seq {
        let dr = rb.decode(Role::Target, cr.view(), cur, pos).unwrap();
        let ds = sb.decode(Role::Target, cs.view(), cur, pos).unwrap();
        assert!(rel_err(&ds.logits, &dr.logits) <= TOL, "pos {pos} logits");
        cr.commit_row(&dr.k_row, &dr.v_row, pos);
        cs.commit_row(&ds.k_row, &ds.v_row, pos);
        cur = (cur + 13) % cfg.vocab as u32;
    }
    assert!(rb.decode(Role::Target, cr.view(), cur, cfg.max_seq).is_err());
    assert!(sb.decode(Role::Target, cs.view(), cur, cfg.max_seq).is_err());
}

fn argmax(xs: &[f32]) -> usize {
    let mut best = 0usize;
    for (i, &x) in xs.iter().enumerate() {
        if x > xs[best] {
            best = i;
        }
    }
    best
}

/// Greedy decode chain over `cache`, teacher-forced to follow `stream`
/// when given one (the cell commits its *own* KV rows either way).
/// Returns the per-step argmax choices and the per-step top-2 logit gaps
/// of this backend's own logits.
fn greedy_chain(
    be: &dyn Backend,
    prompt: &[i32],
    steps: usize,
    mut cache: KvCache,
    force: Option<&[u32]>,
) -> (Vec<u32>, Vec<f32>) {
    let pre = be.prefill(Role::Target, prompt, prompt.len()).unwrap();
    cache.commit_prefill(&pre.k_rows, &pre.v_rows, be.meta().s_pre, prompt.len());
    let mut choices = Vec::with_capacity(steps);
    let mut gaps = Vec::with_capacity(steps);
    let mut logits = pre.logits;
    let mut pos = prompt.len();
    for j in 0..steps {
        let top = argmax(&logits) as u32;
        let mut second = f32::NEG_INFINITY;
        for (i, &l) in logits.iter().enumerate() {
            if i != top as usize && l > second {
                second = l;
            }
        }
        choices.push(top);
        gaps.push(logits[top as usize] - second);
        let next = force.map_or(top, |s| s[j]);
        let d = be.decode(Role::Target, cache.view(), next, pos).unwrap();
        cache.commit_row(&d.k_row, &d.v_row, pos);
        pos += 1;
        logits = d.logits;
    }
    (choices, gaps)
}

/// End-to-end greedy divergence bound per (backend × kv-dtype): along the
/// scalar reference's own greedy path, each cell's argmax must agree with
/// the reference at every step where the reference's top-2 logit gap
/// exceeds the cell's error margin. Disagreement with a *wide* gap means
/// the cell's logits are off by more than its error budget — the failure
/// this test exists to catch. The f32 cells carry tight margins (paged
/// f32 under `cpu-ref` carries none: bit-exact); the lossy dtypes carry
/// budgets sized to half-precision rounding and int8 affine-code error.
#[test]
fn e2e_greedy_divergence_bounded_per_backend_and_kv_dtype() {
    let cfg = CpuModelConfig::tiny();
    let rb = CpuRefBackend::new(&cfg, 11);
    let sb = CpuSimdBackend::new(&cfg, 11);
    let prompt = [7i32, 3, 11, 5, 9, 2];
    let steps = 24usize;

    // the reference path: cpu-ref over contiguous f32
    let (ref_stream, ref_gaps) =
        greedy_chain(&rb, &prompt, steps, KvCache::new(rb.dims(Role::Target)), None);

    // margin per cell: the logit-gap below which an argmax flip is
    // attributable to the cell's error budget rather than a bug
    let cells: [(&dyn Backend, KvDtype, f32); 6] = [
        (&rb, KvDtype::F32, 0.0), // bit-exact rung: no margin at all
        (&rb, KvDtype::F16, 0.5),
        (&rb, KvDtype::Int8, 2.0),
        (&sb, KvDtype::F32, 0.01),
        (&sb, KvDtype::F16, 0.5),
        (&sb, KvDtype::Int8, 2.0),
    ];
    for (be, dtype, margin) in cells {
        let pool = BlockPool::with_dtype(be.dims(Role::Target), 4, None, dtype);
        let (cell_stream, _) =
            greedy_chain(be, &prompt, steps, KvCache::paged(&pool), Some(&ref_stream));
        let label = format!("{}/{}", be.name(), dtype.name());
        for j in 0..steps {
            if cell_stream[j] != ref_stream[j] {
                assert!(
                    ref_gaps[j] < margin,
                    "{label} step {j}: argmax {} != ref {} with wide gap {:.3} (margin {margin})",
                    cell_stream[j],
                    ref_stream[j],
                    ref_gaps[j]
                );
            }
        }
        // within-cell determinism: the same cell replayed is identical
        let pool2 = BlockPool::with_dtype(be.dims(Role::Target), 4, None, dtype);
        let (replay, _) =
            greedy_chain(be, &prompt, steps, KvCache::paged(&pool2), Some(&ref_stream));
        assert_eq!(replay, cell_stream, "{label}: replay not deterministic");
    }
}
