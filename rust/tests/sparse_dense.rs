//! Sparse-vs-dense equality guarantees for the tentpole representation
//! change:
//!
//! * property tests: every pair kernel (overlap / l1 / tv / kl / residual)
//!   agrees between [`Dist`] and [`SparseDist`] to ≤1e-6 (f32 kernels) on
//!   randomized supports — including disjoint supports, singleton supports
//!   and zero-residual-mass cases;
//! * the five OT solvers' branching calculators agree to ≤1e-12 (f64);
//! * all eight verifiers produce **identical verdicts** (τ, accepted nodes,
//!   bonus token) on dense trees and their sparse twins under the same
//!   seeded rng;
//! * the Eq. 3 estimators and the shared-branching scorer agree to ≤1e-12
//!   across representations, and the frozen per-action oracle works on
//!   sparse supersets too.

mod common;

use common::superset::{make_topp_superset, ot_solvers, sparsify_superset};
use common::{make_topp_tree, random_topp_dist, sparsify_tree};
use specdelay::dist::{Dist, DistStorage, NodeDist, SamplingConfig, SparseDist};
use specdelay::util::Pcg64;
use specdelay::verify::{all_verifiers, expected_accepted};
use specdelay::selector::{score_superset, score_superset_per_action};

/// The env knob really selects the storage, and the global-storage
/// constructor produces values identical to both explicit oracles — this
/// is what the CI step that reruns this suite under
/// `SPECDELAY_DENSE_DISTS=1` actually exercises.
#[test]
fn global_storage_honors_env_knob() {
    let dense_selected = std::env::var("SPECDELAY_DENSE_DISTS")
        .map(|v| v == "1" || v.eq_ignore_ascii_case("true"))
        .unwrap_or(false);
    let expect = if dense_selected { DistStorage::Dense } else { DistStorage::Sparse };
    assert_eq!(DistStorage::global(), expect, "env knob not honored");

    let mut rng = Pcg64::seeded(0x9b);
    for case in 0..20usize {
        let v = 8 + case % 40;
        let logits: Vec<f32> = (0..v).map(|_| rng.next_f32() * 9.0).collect();
        for &tp in &[0.85f32, 1.0] {
            let cfg = SamplingConfig::new(1.0, tp);
            let global = NodeDist::from_logits(&logits, cfg, DistStorage::global());
            assert_eq!(global.is_sparse(), expect == DistStorage::Sparse);
            let dense = NodeDist::from_logits(&logits, cfg, DistStorage::Dense);
            let sparse = NodeDist::from_logits(&logits, cfg, DistStorage::Sparse);
            assert_eq!(global.to_dense(), dense.to_dense(), "case {case} top_p {tp}");
            assert_eq!(global.to_dense(), sparse.to_dense(), "case {case} top_p {tp}");
        }
    }
}

/// Random distribution with a bernoulli-masked support (possibly very
/// sparse); always has at least one positive entry.
fn masked_dist(v: usize, rng: &mut Pcg64, keep_prob: f64) -> Dist {
    loop {
        let mut d: Vec<f32> = (0..v)
            .map(|_| {
                if rng.next_f64() < keep_prob {
                    rng.next_f32() + 1e-3
                } else {
                    0.0
                }
            })
            .collect();
        let s: f32 = d.iter().sum();
        if s > 0.0 {
            for x in d.iter_mut() {
                *x /= s;
            }
            return Dist(d);
        }
    }
}

fn check_pair_kernels(pd: &Dist, qd: &Dist, label: &str) {
    let ps = SparseDist::from_dense(pd);
    let qs = SparseDist::from_dense(qd);
    let tol = 1e-6f32;
    assert!(
        (SparseDist::overlap(&ps, &qs) - Dist::overlap(pd, qd)).abs() <= tol,
        "{label}: overlap"
    );
    assert!((SparseDist::l1(&ps, &qs) - Dist::l1(pd, qd)).abs() <= tol, "{label}: l1");
    assert!((SparseDist::tv(&ps, &qs) - Dist::tv(pd, qd)).abs() <= tol, "{label}: tv");
    assert!((ps.kl(&qs) - pd.kl(qd)).abs() <= tol, "{label}: kl");
    assert!((ps.entropy() - pd.entropy()).abs() <= tol, "{label}: entropy");

    let mut rd = Dist::default();
    let mut rs = SparseDist::default();
    let okd = Dist::residual_into(pd, qd, &mut rd);
    let oks = SparseDist::residual_into(&ps, &qs, &mut rs);
    assert_eq!(okd, oks, "{label}: residual mass flag");
    if okd {
        let rsd = rs.to_dense();
        assert_eq!(rd.0.len(), rsd.0.len(), "{label}: residual len");
        for (t, (&a, &b)) in rd.0.iter().zip(&rsd.0).enumerate() {
            assert!((a - b).abs() <= tol, "{label}: residual[{t}] {a} vs {b}");
        }
        // samples from the residual draw the identical stream
        let mut r1 = Pcg64::seeded(0xbeef);
        let mut r2 = Pcg64::seeded(0xbeef);
        for _ in 0..200 {
            assert_eq!(rd.sample(&mut r1), rs.sample(&mut r2), "{label}: residual sample");
        }
    }
    // sampling the dists themselves
    let mut r1 = Pcg64::seeded(0xabc);
    let mut r2 = Pcg64::seeded(0xabc);
    for _ in 0..200 {
        assert_eq!(pd.sample(&mut r1), ps.sample(&mut r2), "{label}: sample");
    }
}

#[test]
fn kernels_agree_on_randomized_supports() {
    let mut rng = Pcg64::seeded(0x51);
    for case in 0..200usize {
        let v = 4 + case % 61;
        let keep = [0.15, 0.5, 0.9][case % 3];
        let p = masked_dist(v, &mut rng, keep);
        let q = masked_dist(v, &mut rng, keep);
        check_pair_kernels(&p, &q, &format!("masked case {case}"));
    }
    // nucleus-truncated supports (the production shape)
    for case in 0..60usize {
        let v = 16 + case % 49;
        let p = random_topp_dist(v, &mut rng, 0.8);
        let q = random_topp_dist(v, &mut rng, 0.95);
        check_pair_kernels(&p, &q, &format!("topp case {case}"));
    }
}

#[test]
fn kernels_agree_on_edge_supports() {
    // disjoint supports
    let p = Dist(vec![0.6, 0.4, 0.0, 0.0]);
    let q = Dist(vec![0.0, 0.0, 0.3, 0.7]);
    check_pair_kernels(&p, &q, "disjoint");
    // singleton supports
    let p1 = Dist(vec![0.0, 1.0, 0.0]);
    let q1 = Dist(vec![0.0, 0.0, 1.0]);
    check_pair_kernels(&p1, &q1, "singletons disjoint");
    check_pair_kernels(&p1, &p1, "singleton identical");
    // zero residual mass: p ≤ q pointwise (p == q)
    let p2 = Dist(vec![0.25, 0.25, 0.5]);
    check_pair_kernels(&p2, &p2, "identical");
    // one side full-support vs sparse other
    let p3 = Dist(vec![0.25, 0.25, 0.25, 0.25]);
    let q3 = Dist(vec![0.0, 1.0, 0.0, 0.0]);
    check_pair_kernels(&p3, &q3, "full vs singleton");
    check_pair_kernels(&q3, &p3, "singleton vs full");
}

#[test]
fn branching_calculators_agree() {
    let mut rng = Pcg64::seeded(0xb7a);
    let solvers = ot_solvers();
    for case in 0..40usize {
        let v = 8 + case % 33;
        let pd = NodeDist::from(masked_dist(v, &mut rng, 0.5));
        let qd = NodeDist::from(masked_dist(v, &mut rng, 0.5));
        let (ps, qs) = (pd.sparsify(), qd.sparsify());
        // draft xs from q (fall back to token 0 when q is ultra sparse)
        let k = 1 + case % 4;
        let xs: Vec<u32> = (0..k).map(|_| qd.sample(&mut rng) as u32).collect();
        for (name, solver) in &solvers {
            let dense = solver.branching(&pd, &qd, &xs);
            let sparse = solver.branching(&ps, &qs, &xs);
            assert_eq!(dense.len(), sparse.len());
            for (i, (a, b)) in dense.iter().zip(&sparse).enumerate() {
                assert!(
                    (a - b).abs() <= 1e-12,
                    "case {case} {name} pos {i}: dense {a} vs sparse {b}"
                );
            }
        }
    }
}

/// The acceptance criterion: identical verdicts (τ, accepted node indices,
/// bonus/correction token) for all eight verifiers under seeded rng, dense
/// trees vs their sparse twins, across top-p regimes.
#[test]
fn verdicts_identical_across_representations() {
    let mut rng = Pcg64::seeded(0x7e57);
    for &top_p in &[0.8f32, 0.95, 1.0] {
        for case in 0..6usize {
            let dense_tree = make_topp_tree(&mut rng, 97, top_p);
            let sparse_tree = sparsify_tree(&dense_tree);
            let mut fallback_dense = dense_tree.clone();
            fallback_dense.path_draws = None;
            let mut fallback_sparse = sparse_tree.clone();
            fallback_sparse.path_draws = None;
            for v in all_verifiers() {
                for seed in 0..40u64 {
                    let mut r1 = Pcg64::seeded(seed);
                    let mut r2 = Pcg64::seeded(seed);
                    let a = v.verify(&dense_tree, &mut r1);
                    let b = v.verify(&sparse_tree, &mut r2);
                    assert_eq!(
                        a.accepted,
                        b.accepted,
                        "top_p {top_p} case {case} {} seed {seed}: accepted",
                        v.name()
                    );
                    assert_eq!(
                        a.correction,
                        b.correction,
                        "top_p {top_p} case {case} {} seed {seed}: correction",
                        v.name()
                    );
                    // Traversal's fallback (rebuilt path draws) too
                    let mut r3 = Pcg64::seeded(seed);
                    let mut r4 = Pcg64::seeded(seed);
                    let c = v.verify(&fallback_dense, &mut r3);
                    let d = v.verify(&fallback_sparse, &mut r4);
                    assert_eq!(c.accepted, d.accepted, "{} fallback accepted", v.name());
                    assert_eq!(c.correction, d.correction, "{} fallback correction", v.name());
                }
            }
        }
    }
}

#[test]
fn eq3_estimators_agree() {
    let mut rng = Pcg64::seeded(0xe93);
    for case in 0..6usize {
        let dense_tree = make_topp_tree(&mut rng, 64, 0.9);
        let sparse_tree = sparsify_tree(&dense_tree);
        for (name, solver) in ot_solvers() {
            let a = expected_accepted(&dense_tree, solver.as_ref());
            let b = expected_accepted(&sparse_tree, solver.as_ref());
            assert!(
                (a - b).abs() <= 1e-12,
                "case {case} {name}: dense {a} vs sparse {b}"
            );
        }
    }
}

#[test]
fn superset_scorers_agree() {
    let mut rng = Pcg64::seeded(0x5c0);
    let solvers = ot_solvers();
    let ss = make_topp_superset(&mut rng, 32, 0.9);
    let ss_sparse = sparsify_superset(&ss);
    let dense = score_superset(&ss, &solvers);
    let sparse = score_superset(&ss_sparse, &solvers);
    for (si, (d_row, s_row)) in dense.iter().zip(&sparse).enumerate() {
        for (ai, (a, b)) in d_row.iter().zip(s_row).enumerate() {
            assert!(
                (a - b).abs() <= 1e-12,
                "{} action {ai}: dense {a} vs sparse {b}",
                solvers[si].0
            );
        }
    }
    // the frozen per-action oracle also runs on sparse storage and agrees
    let oracle = score_superset_per_action(&ss_sparse, &solvers);
    for (si, (o_row, s_row)) in oracle.iter().zip(&sparse).enumerate() {
        for (ai, (a, b)) in o_row.iter().zip(s_row).enumerate() {
            assert!(
                (a - b).abs() <= 1e-12,
                "{} action {ai}: sparse oracle {a} vs shared {b}",
                solvers[si].0
            );
        }
    }
}
