//! Synthetic superset samples and the OT solver roster for the
//! selector-score bench and the shared-vs-per-action equality tests (pure
//! rust, no PJRT, no model artifacts).

use specdelay::dist::{Dist, NodeDist};
use specdelay::selector::{BranchChain, Superset, K_MAX, L1_MAX, L2_MAX};
use specdelay::util::Pcg64;
use specdelay::verify::{self, OtlpSolver};

use super::{random_dist, random_topp_dist};

/// The five distinct OT solvers, in `benchkit::experiments::OT_ALGOS`
/// spirit ("NaiveTree" shares the "Naive" solver and is omitted).
pub fn ot_solvers() -> Vec<(&'static str, Box<dyn OtlpSolver>)> {
    ["NSS", "Naive", "SpecTr", "SpecInfer", "Khisti"]
        .iter()
        .map(|&n| (n, verify::ot_solver(n).expect("known solver")))
        .collect()
}

/// Draft-shaped superset sample built from `gen_p`/`gen_q` (dense storage):
/// full trunk of L1_MAX plus K_MAX chains of L2_MAX at every trunk depth,
/// p and q at every node.
fn make_superset_with(
    rng: &mut Pcg64,
    v: usize,
    mut gen_p: impl FnMut(&mut Pcg64) -> Dist,
    mut gen_q: impl FnMut(&mut Pcg64) -> Dist,
) -> Superset {
    let trunk_q: Vec<NodeDist> = (0..L1_MAX).map(|_| NodeDist::from(gen_q(rng))).collect();
    let trunk_p: Vec<NodeDist> = (0..=L1_MAX).map(|_| NodeDist::from(gen_p(rng))).collect();
    let mut trunk_tokens = vec![rng.next_below(v) as u32];
    for q in &trunk_q {
        trunk_tokens.push(q.sample(rng) as u32);
    }
    let mut branches = Vec::with_capacity(L1_MAX + 1);
    for _j in 0..=L1_MAX {
        let mut per_branch = Vec::with_capacity(K_MAX);
        for _b in 0..K_MAX {
            let q: Vec<NodeDist> = (0..L2_MAX).map(|_| NodeDist::from(gen_q(rng))).collect();
            let p: Vec<NodeDist> = (0..=L2_MAX).map(|_| NodeDist::from(gen_p(rng))).collect();
            let tokens: Vec<u32> = q.iter().map(|d| d.sample(rng) as u32).collect();
            per_branch.push(BranchChain { tokens, q, p });
        }
        branches.push(per_branch);
    }
    Superset { trunk_tokens, trunk_q, trunk_p, branches }
}

/// Full-support sample. Chain tokens are drawn from sharp draft
/// distributions so chains share prefixes often enough to exercise the
/// scorers' merge and duplicate-child paths.
pub fn make_superset(rng: &mut Pcg64, v: usize) -> Superset {
    make_superset_with(rng, v, |r| random_dist(v, r, 2.0), |r| random_dist(v, r, 6.0))
}

/// Truncated-support sample: every p/q runs through top-p (dense storage;
/// pair with [`sparsify_superset`] for the sparse twin).
pub fn make_topp_superset(rng: &mut Pcg64, v: usize, top_p: f32) -> Superset {
    make_superset_with(
        rng,
        v,
        |r| random_topp_dist(v, r, top_p),
        |r| random_topp_dist(v, r, top_p),
    )
}

/// Sparse twin: identical tokens and distribution values, sparse storage.
pub fn sparsify_superset(ss: &Superset) -> Superset {
    Superset {
        trunk_tokens: ss.trunk_tokens.clone(),
        trunk_q: ss.trunk_q.iter().map(|d| d.sparsify()).collect(),
        trunk_p: ss.trunk_p.iter().map(|d| d.sparsify()).collect(),
        branches: ss
            .branches
            .iter()
            .map(|per| {
                per.iter()
                    .map(|c| BranchChain {
                        tokens: c.tokens.clone(),
                        q: c.q.iter().map(|d| d.sparsify()).collect(),
                        p: c.p.iter().map(|d| d.sparsify()).collect(),
                    })
                    .collect()
            })
            .collect(),
    }
}
