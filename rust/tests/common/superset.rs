//! Synthetic superset samples and the OT solver roster for the
//! selector-score bench and the shared-vs-per-action equality tests (pure
//! rust, no PJRT, no model artifacts).

use specdelay::dist::Dist;
use specdelay::selector::{BranchChain, Superset, K_MAX, L1_MAX, L2_MAX};
use specdelay::util::Pcg64;
use specdelay::verify::{self, OtlpSolver};

use super::random_dist;

/// The five distinct OT solvers, in `benchkit::experiments::OT_ALGOS`
/// spirit ("NaiveTree" shares the "Naive" solver and is omitted).
pub fn ot_solvers() -> Vec<(&'static str, Box<dyn OtlpSolver>)> {
    ["NSS", "Naive", "SpecTr", "SpecInfer", "Khisti"]
        .iter()
        .map(|&n| (n, verify::ot_solver(n).expect("known solver")))
        .collect()
}

/// Draft-shaped superset sample over a synthetic vocabulary: full trunk of
/// L1_MAX plus K_MAX chains of L2_MAX at every trunk depth, p and q at
/// every node. Chain tokens are drawn from sharp draft distributions so
/// chains share prefixes often enough to exercise the scorers' merge and
/// duplicate-child paths.
pub fn make_superset(rng: &mut Pcg64, v: usize) -> Superset {
    let trunk_q: Vec<Dist> = (0..L1_MAX).map(|_| random_dist(v, rng, 1.0)).collect();
    let trunk_p: Vec<Dist> = (0..=L1_MAX).map(|_| random_dist(v, rng, 2.0)).collect();
    let mut trunk_tokens = vec![rng.next_below(v) as u32];
    for q in &trunk_q {
        trunk_tokens.push(q.sample(rng) as u32);
    }
    let mut branches = Vec::with_capacity(L1_MAX + 1);
    for _j in 0..=L1_MAX {
        let mut per_branch = Vec::with_capacity(K_MAX);
        for _b in 0..K_MAX {
            let q: Vec<Dist> = (0..L2_MAX).map(|_| random_dist(v, rng, 6.0)).collect();
            let p: Vec<Dist> = (0..=L2_MAX).map(|_| random_dist(v, rng, 2.0)).collect();
            let tokens: Vec<u32> = q.iter().map(|d| d.sample(rng) as u32).collect();
            per_branch.push(BranchChain { tokens, q, p });
        }
        branches.push(per_branch);
    }
    Superset { trunk_tokens, trunk_q, trunk_p, branches }
}
