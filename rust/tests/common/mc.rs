//! Shared seeded Monte-Carlo machinery for the statistical losslessness
//! suites — the single entry point `tests/e2e_serve.rs` and
//! `tests/losslessness.rs` previously hand-rolled three variants of:
//!
//! * [`replay_block_conditionals`] — replay one speculation block many
//!   times from a cloned prefilled sequence on its own seeded rng stream,
//!   collecting first-token counts and second-token conditional counts;
//! * [`check_counts`] — the per-token binomial tolerance assertion
//!   (5σ + slack) against an exact distribution;
//! * [`assert_chi_square`] — the chi-square goodness-of-fit assertion over
//!   the same counts (sparse bins pooled), powered by
//!   [`specdelay::util::stats::chi_square_stat`].
//!
//! Sample counts are env-tunable via `SPECDELAY_MC_SAMPLES` so CI can
//! smoke the suites cheaply without code changes.

use std::collections::HashMap;

use specdelay::coordinator::{Sequence, SpecEngine};
use specdelay::draft::Action;
use specdelay::util::stats::{chi_square_sf, chi_square_stat};
use specdelay::util::Pcg64;
use specdelay::verify::Verifier;

/// Monte-Carlo sample count: `SPECDELAY_MC_SAMPLES` when set (and ≥ 1),
/// otherwise `default`.
pub fn mc_samples(default: usize) -> usize {
    std::env::var("SPECDELAY_MC_SAMPLES")
        .ok()
        .and_then(|v| v.parse::<usize>().ok())
        .filter(|&n| n >= 1)
        .unwrap_or(default)
}

/// First-token counts and second-token conditional counts from `n` block
/// replays.
pub struct BlockConditionals {
    /// `counts[t]` = times token `t` was emitted first.
    pub first: Vec<usize>,
    /// `second[t1][t2]` = times `t2` followed a first token `t1`.
    pub second: HashMap<u32, Vec<usize>>,
}

/// Replay one speculation block `n` times from the prefilled `base`
/// sequence, each round on a fresh clone and the seeded rng stream
/// `Pcg64::new(seed, round)`, and tally the emitted-stream conditionals.
/// Deterministic given `(spec storage, verifier, action, seed, n)` — two
/// storages that are bit-identical produce *equal* tallies.
pub fn replay_block_conditionals(
    spec: &SpecEngine<'_>,
    base: &Sequence,
    verifier: &dyn Verifier,
    action: Action,
    vocab: usize,
    n: usize,
    seed: u64,
) -> BlockConditionals {
    let mut first = vec![0usize; vocab];
    let mut second: HashMap<u32, Vec<usize>> = HashMap::new();
    for round in 0..n {
        let mut seq = base.clone();
        let mut rng = Pcg64::new(seed, round as u64);
        let b = spec
            .step(&mut seq, verifier, action, &mut rng)
            .expect("block replay failed");
        assert!(b.emitted >= 1, "{}: empty block", verifier.name());
        let emitted = &seq.tokens[seq.prompt_len..];
        first[emitted[0] as usize] += 1;
        if emitted.len() >= 2 {
            second.entry(emitted[0]).or_insert_with(|| vec![0; vocab])[emitted[1] as usize] += 1;
        }
    }
    BlockConditionals { first, second }
}

/// Per-token binomial tolerance check: every empirical frequency must sit
/// within 5σ + `slack` of the exact probability (the shared tolerance
/// formula of the e2e and toy-LM losslessness suites).
pub fn check_counts(label: &str, counts: &[usize], want: &[f32], n: usize, slack: f64) {
    for (t, &c) in counts.iter().enumerate() {
        let emp = c as f64 / n as f64;
        let w = want[t] as f64;
        let tol = 5.0 * (w * (1.0 - w) / n as f64).sqrt() + slack;
        assert!(
            (emp - w).abs() < tol,
            "{label} token {t}: emp {emp:.4} vs target {w:.4} (n={n}, tol {tol:.4})"
        );
    }
}

/// Chi-square goodness-of-fit assertion: the counts' p-value against the
/// exact distribution must stay above `p_floor` (bins with expectation
/// < 5 pooled; silently passes when fewer than two effective bins remain —
/// nothing to test). Under a correct sampler p-values are uniform, so a
/// floor of 1e-6 false-fails one seeded run in a million while any real
/// conditional bug drives the p-value to ~0 at these sample sizes.
pub fn assert_chi_square(label: &str, counts: &[usize], want: &[f32], n: usize, p_floor: f64) {
    let expected: Vec<f64> = want.iter().map(|&w| w as f64 * n as f64).collect();
    let Some((stat, dof)) = chi_square_stat(counts, &expected, 5.0) else {
        return;
    };
    let p = chi_square_sf(stat, dof);
    assert!(
        p > p_floor,
        "{label}: chi-square {stat:.2} (dof {dof}) p = {p:.3e} below {p_floor:.0e} (n={n})"
    );
}
