//! Shared support for the integration tests and the default-build benches:
//! the counting global allocator, the synthetic delayed-tree workload
//! (`tests/alloc_free.rs` + `benches/verify_hot.rs`), and the synthetic
//! superset workload (`tests/selector_score.rs` +
//! `benches/selector_score.rs`, see [`superset`]). Keeping these in one
//! module guarantees the configuration the tests assert is exactly the one
//! the benches measure.
//!
//! Each including binary uses a subset of these helpers, hence the
//! module-wide dead_code allowance.
#![allow(dead_code)]

pub mod superset;

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};

use specdelay::dist::Dist;
use specdelay::tree::{DraftTree, PathDraws, Provenance};
use specdelay::util::Pcg64;

/// Global allocator that counts every alloc/realloc/alloc_zeroed call.
pub struct CountingAlloc;

static ALLOC_COUNT: AtomicU64 = AtomicU64::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOC_COUNT.fetch_add(1, Ordering::SeqCst);
        System.alloc(layout)
    }
    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOC_COUNT.fetch_add(1, Ordering::SeqCst);
        System.realloc(ptr, layout, new_size)
    }
    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        ALLOC_COUNT.fetch_add(1, Ordering::SeqCst);
        System.alloc_zeroed(layout)
    }
}

/// Total allocation calls so far (diff two reads to count a region).
pub fn allocs() -> u64 {
    ALLOC_COUNT.load(Ordering::SeqCst)
}

/// Random normalized distribution; `sharp` > 1 concentrates mass.
pub fn random_dist(v: usize, rng: &mut Pcg64, sharp: f32) -> Dist {
    let mut d: Vec<f32> = (0..v).map(|_| rng.next_f32().powf(sharp) + 1e-4).collect();
    let sum: f32 = d.iter().sum();
    for x in d.iter_mut() {
        *x /= sum;
    }
    Dist(d)
}

/// Delayed tree: trunk of 2, then 3 branches of 3 — the paper's moderate
/// (K=3, L1=2, L2=3) shape, 12 nodes. p and q are set at every node and
/// path draws are recorded with `shared_edges = 2`.
pub fn make_tree(rng: &mut Pcg64, v: usize) -> DraftTree {
    let mut t = DraftTree::new(5);
    let mut node = 0;
    for step in 0..2 {
        let q = random_dist(v, rng, 1.0);
        let tok = q.sample(rng) as u32;
        t.set_q(node, q);
        t.set_p(node, random_dist(v, rng, 2.0));
        node = t.add_child(node, tok, Provenance::Trunk { step: step + 1 });
    }
    let bp = node;
    let mut paths = Vec::new();
    for b in 0..3 {
        let mut cur = bp;
        for step in 0..3 {
            if t.nodes[cur].q.is_none() {
                t.set_q(cur, random_dist(v, rng, 1.0));
            }
            if t.nodes[cur].p.is_none() {
                t.set_p(cur, random_dist(v, rng, 2.0));
            }
            let tok = t.nodes[cur].q.as_ref().unwrap().sample(rng) as u32;
            cur = t.add_child(cur, tok, Provenance::Branch { branch: b, step: step + 1 });
        }
        paths.push(t.path_nodes(cur));
    }
    for i in 0..t.len() {
        if t.nodes[i].p.is_none() {
            t.set_p(i, random_dist(v, rng, 2.0));
        }
        if t.nodes[i].q.is_none() {
            t.set_q(i, random_dist(v, rng, 1.0));
        }
    }
    t.path_draws = Some(PathDraws { paths, shared_edges: 2 });
    t
}
