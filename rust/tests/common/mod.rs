//! Shared support for the integration tests and the default-build benches:
//! the counting global allocator, the synthetic delayed-tree workload
//! (`tests/alloc_free.rs` + `benches/verify_hot.rs`), the synthetic
//! superset workload (`tests/selector_score.rs` +
//! `benches/selector_score.rs`, see [`superset`]), and the seeded
//! Monte-Carlo machinery of the statistical losslessness suites
//! (`tests/e2e_serve.rs` + `tests/losslessness.rs`, see [`mc`]). Keeping
//! these in one module guarantees the configuration the tests assert is
//! exactly the one the benches measure.
//!
//! Each including binary uses a subset of these helpers, hence the
//! module-wide dead_code allowance.
#![allow(dead_code)]

pub mod mc;
pub mod superset;

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};

use specdelay::dist::{Dist, SamplingConfig};
use specdelay::tree::{DraftTree, PathDraws, Provenance};
use specdelay::util::Pcg64;

/// Global allocator that counts every alloc/realloc/alloc_zeroed call.
pub struct CountingAlloc;

static ALLOC_COUNT: AtomicU64 = AtomicU64::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOC_COUNT.fetch_add(1, Ordering::SeqCst);
        System.alloc(layout)
    }
    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOC_COUNT.fetch_add(1, Ordering::SeqCst);
        System.realloc(ptr, layout, new_size)
    }
    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        ALLOC_COUNT.fetch_add(1, Ordering::SeqCst);
        System.alloc_zeroed(layout)
    }
}

/// Total allocation calls so far (diff two reads to count a region).
pub fn allocs() -> u64 {
    ALLOC_COUNT.load(Ordering::SeqCst)
}

/// Random normalized distribution; `sharp` > 1 concentrates mass.
pub fn random_dist(v: usize, rng: &mut Pcg64, sharp: f32) -> Dist {
    let mut d: Vec<f32> = (0..v).map(|_| rng.next_f32().powf(sharp) + 1e-4).collect();
    let sum: f32 = d.iter().sum();
    for x in d.iter_mut() {
        *x /= sum;
    }
    Dist(d)
}

/// Random *truncated* distribution: sharp logits through the temperature +
/// top-p transform, so the support is a small nucleus (dense storage, zeros
/// outside the nucleus). The workload for the sparse-vs-dense equality
/// tests and the dist_kernels bench.
pub fn random_topp_dist(v: usize, rng: &mut Pcg64, top_p: f32) -> Dist {
    let logits: Vec<f32> = (0..v).map(|_| rng.next_f32() * 10.0).collect();
    Dist::from_logits(&logits, SamplingConfig::new(1.0, top_p))
}

/// Sparse twin of a tree: identical structure and distribution values,
/// sparse storage. Dense/sparse verdict-equality tests run both twins on
/// the same seeded rng.
pub fn sparsify_tree(tree: &DraftTree) -> DraftTree {
    let mut t = tree.clone();
    for n in t.nodes.iter_mut() {
        n.p = n.p.take().map(|d| d.sparsify());
        n.q = n.q.take().map(|d| d.sparsify());
    }
    t
}

/// Delayed tree: trunk of 2, then 3 branches of 3 — the paper's moderate
/// (K=3, L1=2, L2=3) shape, 12 nodes. p and q drawn by `gen_p`/`gen_q` at
/// every node; path draws are recorded with `shared_edges = 2`.
fn make_tree_with(
    rng: &mut Pcg64,
    mut gen_p: impl FnMut(&mut Pcg64) -> Dist,
    mut gen_q: impl FnMut(&mut Pcg64) -> Dist,
) -> DraftTree {
    let mut t = DraftTree::new(5);
    let mut node = 0;
    for step in 0..2 {
        let q = gen_q(rng);
        let tok = q.sample(rng) as u32;
        t.set_q(node, q);
        t.set_p(node, gen_p(rng));
        node = t.add_child(node, tok, Provenance::Trunk { step: step + 1 });
    }
    let bp = node;
    let mut paths = Vec::new();
    for b in 0..3 {
        let mut cur = bp;
        for step in 0..3 {
            if t.nodes[cur].q.is_none() {
                let q = gen_q(rng);
                t.set_q(cur, q);
            }
            if t.nodes[cur].p.is_none() {
                t.set_p(cur, gen_p(rng));
            }
            let tok = t.nodes[cur].q.as_ref().unwrap().sample(rng) as u32;
            cur = t.add_child(cur, tok, Provenance::Branch { branch: b, step: step + 1 });
        }
        paths.push(t.path_nodes(cur));
    }
    for i in 0..t.len() {
        if t.nodes[i].p.is_none() {
            t.set_p(i, gen_p(rng));
        }
        if t.nodes[i].q.is_none() {
            let q = gen_q(rng);
            t.set_q(i, q);
        }
    }
    t.path_draws = Some(PathDraws { paths, shared_edges: 2 });
    t
}

/// The standard full-support workload (dense storage).
pub fn make_tree(rng: &mut Pcg64, v: usize) -> DraftTree {
    make_tree_with(rng, |r| random_dist(v, r, 2.0), |r| random_dist(v, r, 1.0))
}

/// Root-started tree: an optional trunk of `trunk_len` plus `branches`
/// branches of `branch_len`, every path attached at the root and recorded
/// as an independent draw (`shared_edges = 0`) — the geometry the root and
/// greedy drafters produce.
fn make_root_started_tree_with(
    rng: &mut Pcg64,
    mut gen_p: impl FnMut(&mut Pcg64) -> Dist,
    mut gen_q: impl FnMut(&mut Pcg64) -> Dist,
    trunk_len: usize,
    branches: usize,
    branch_len: usize,
) -> DraftTree {
    let mut t = DraftTree::new(5);
    let mut paths = Vec::new();
    // root-started trunk: its own independent path draw, recorded ahead of
    // the branch draws (draft order, matching the greedy drafter)
    if trunk_len > 0 {
        let mut cur = 0;
        for step in 0..trunk_len {
            if t.nodes[cur].q.is_none() {
                t.set_q(cur, gen_q(rng));
            }
            let tok = t.nodes[cur].q.as_ref().unwrap().sample(rng) as u32;
            cur = t.add_child(cur, tok, Provenance::Trunk { step: step + 1 });
        }
        paths.push(t.path_nodes(cur));
    }
    for b in 0..branches {
        let mut cur = 0;
        for step in 0..branch_len {
            if t.nodes[cur].q.is_none() {
                t.set_q(cur, gen_q(rng));
            }
            let tok = t.nodes[cur].q.as_ref().unwrap().sample(rng) as u32;
            cur = t.add_child(cur, tok, Provenance::Branch { branch: b, step: step + 1 });
        }
        paths.push(t.path_nodes(cur));
    }
    for i in 0..t.len() {
        if t.nodes[i].p.is_none() {
            t.set_p(i, gen_p(rng));
        }
        if t.nodes[i].q.is_none() {
            let q = gen_q(rng);
            t.set_q(i, q);
        }
    }
    t.path_draws = Some(PathDraws { paths, shared_edges: 0 });
    t
}

/// Classic root-branching workload (the root drafter's geometry for a
/// shaped (K=3, L1=0, L2=3) action): no trunk, 3 independent branches of
/// 3 from the root, 9 non-root nodes.
pub fn make_root_tree(rng: &mut Pcg64, v: usize) -> DraftTree {
    make_root_started_tree_with(
        rng,
        |r| random_dist(v, r, 2.0),
        |r| random_dist(v, r, 1.0),
        0,
        3,
        3,
    )
}

/// Greedy multi-path workload (the greedy drafter's geometry): a
/// root-started trunk of 2 plus 3 root-started branches of 3 — 4
/// independent path draws over 11 non-root nodes.
pub fn make_greedy_tree(rng: &mut Pcg64, v: usize) -> DraftTree {
    make_root_started_tree_with(
        rng,
        |r| random_dist(v, r, 2.0),
        |r| random_dist(v, r, 1.0),
        2,
        3,
        3,
    )
}

/// Truncated-support workload: every p/q runs through top-p, so the sparse
/// twin ([`sparsify_tree`]) carries genuinely small supports. Dense storage
/// (the oracle side of the pair).
pub fn make_topp_tree(rng: &mut Pcg64, v: usize, top_p: f32) -> DraftTree {
    make_tree_with(rng, |r| random_topp_dist(v, r, top_p), |r| random_topp_dist(v, r, top_p))
}
