//! Property-based fuzz of the paged KV cache against a contiguous shadow.
//!
//! Each seeded sequence drives a random interleaving of lane operations —
//! create, commit (row / prefill / rollout span / tree row), copy-on-write
//! fork (`clone_prefix`), prefix refresh (`copy_prefix_from`), retire —
//! over several lanes sharing one [`BlockPool`], applying every op
//! identically to a [`ContiguousKv`] shadow. After **every** op it
//! asserts:
//!
//! * allocator invariants via [`BlockPool::validate`]: block conservation
//!   (`created == free + live`, i.e. no block is ever lost or
//!   double-freed) and that free-list blocks are referenced by nothing
//!   (refcount conservation — a retired block can never be read or forked);
//! * pool/lane accounting: unique live blocks bounded by the lanes' table
//!   residency (sharing can only reduce, never grow, the unique count);
//! * **bitwise read equality** with the shadow on every row both
//!   representations define (rows invalidated by a prefix op are excluded
//!   on both sides — the shared "must not be read" contract).
//!
//! The sequence count (default 1000, the acceptance floor) is tunable via
//! `SPECDELAY_FUZZ_SEQS`.
//!
//! A second fuzz layers the cross-request [`PrefixCache`] on top: random
//! interleavings of lane admission (`match_into` + warm commit of the
//! uncached tail), retirement `insert`, LRU `reclaim`, `clear` and lane
//! drops, asserting after every op that warm lanes read bit-identical to a
//! cold contiguous shadow, that both pools conserve blocks, and that
//! dropping the cache leaks nothing.

use specdelay::kvcache::{BlockPool, ContiguousKv, KvCache, PrefixCache};
use specdelay::runtime::ModelDims;
use specdelay::util::Pcg64;

struct Lane {
    paged: KvCache,
    shadow: ContiguousKv,
    /// Rows both representations hold defined (written since the last
    /// prefix op that invalidated them).
    defined: Vec<bool>,
}

fn rand_vec(rng: &mut Pcg64, n: usize) -> Vec<f32> {
    (0..n).map(|_| rng.next_f32() * 8.0 - 4.0).collect()
}

fn rand_below(rng: &mut Pcg64, n: usize) -> usize {
    (rng.next_f32() as f64 * n as f64) as usize % n.max(1)
}

fn check_lane(lane: &Lane, d: &ModelDims, ctx: &str) {
    assert_eq!(lane.paged.len(), lane.shadow.len, "{ctx}: len diverged");
    for (pos, &def) in lane.defined.iter().enumerate() {
        if !def {
            continue;
        }
        for l in 0..d.n_layers {
            for hh in 0..d.n_heads {
                let (pk, pv) = lane.paged.read_row(l, hh, pos);
                let (sk, sv) = lane.shadow.row(l, hh, pos);
                assert_eq!(pk, sk, "{ctx}: K row diverged l={l} h={hh} pos={pos}");
                assert_eq!(pv, sv, "{ctx}: V row diverged l={l} h={hh} pos={pos}");
            }
        }
    }
}

fn check_all(pool: &BlockPool, lanes: &[Lane], d: &ModelDims, ctx: &str) {
    pool.validate().unwrap_or_else(|e| panic!("{ctx}: {e}"));
    let resident: usize = lanes
        .iter()
        .map(|l| l.paged.as_paged().unwrap().resident_blocks())
        .sum();
    let max_resident = lanes
        .iter()
        .map(|l| l.paged.as_paged().unwrap().resident_blocks())
        .max()
        .unwrap_or(0);
    let live = pool.live_blocks();
    assert!(live <= resident, "{ctx}: live {live} > table refs {resident}");
    assert!(live >= max_resident, "{ctx}: live {live} < widest lane {max_resident}");
    for lane in lanes {
        check_lane(lane, d, ctx);
    }
}

#[test]
fn fuzz_alloc_fork_write_retire_against_contiguous_shadow() {
    let seqs: usize = std::env::var("SPECDELAY_FUZZ_SEQS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(1000);
    let ops_per_seq = 30usize;
    let max_lanes = 5usize;

    for seq in 0..seqs as u64 {
        // alternate shapes: multi-head vs the single-head span-copy path
        let d = if seq % 2 == 0 {
            ModelDims { n_layers: 1, d_model: 4, n_heads: 2, d_head: 2, vocab: 7, max_seq: 24 }
        } else {
            ModelDims { n_layers: 2, d_model: 4, n_heads: 1, d_head: 3, vocab: 7, max_seq: 24 }
        };
        let bt = [1usize, 3, 5, 8][(seq % 4) as usize];
        let pool = BlockPool::new(d, bt, None);
        let mut rng = Pcg64::new(0xFA22, seq);
        let mut lanes: Vec<Lane> = Vec::new();
        let (lyr, h, dh, s) = (d.n_layers, d.n_heads, d.d_head, d.max_seq);

        for op in 0..ops_per_seq {
            let ctx = format!("seq {seq} op {op} (bt {bt})");
            let choice = rand_below(&mut rng, 8);
            match choice {
                // create a fresh empty lane
                0 => {
                    if lanes.len() < max_lanes {
                        lanes.push(Lane {
                            paged: KvCache::paged(&pool),
                            shadow: ContiguousKv::new(d),
                            defined: vec![false; s],
                        });
                    }
                }
                // single-row commit
                1 if !lanes.is_empty() => {
                    let li = rand_below(&mut rng, lanes.len());
                    let pos = rand_below(&mut rng, s);
                    let row = rand_vec(&mut rng, lyr * h * dh);
                    let vrow = rand_vec(&mut rng, lyr * h * dh);
                    lanes[li].paged.commit_row(&row, &vrow, pos);
                    lanes[li].shadow.commit_row(&row, &vrow, pos);
                    lanes[li].defined[pos] = true;
                }
                // prefill commit
                2 if !lanes.is_empty() => {
                    let li = rand_below(&mut rng, lanes.len());
                    let len = 1 + rand_below(&mut rng, s.min(12));
                    let s_pre = len + rand_below(&mut rng, 4);
                    let rows = rand_vec(&mut rng, lyr * h * s_pre * dh);
                    let vrows = rand_vec(&mut rng, lyr * h * s_pre * dh);
                    lanes[li].paged.commit_prefill(&rows, &vrows, s_pre, len);
                    lanes[li].shadow.commit_prefill(&rows, &vrows, s_pre, len);
                    lanes[li].defined[..len].fill(true);
                }
                // rollout span commit (exercises the per-block coalescing)
                3 if !lanes.is_empty() => {
                    let li = rand_below(&mut rng, lanes.len());
                    let k_paths = 1 + rand_below(&mut rng, 3);
                    let l_steps = 1 + rand_below(&mut rng, 4);
                    let branch = rand_below(&mut rng, k_paths);
                    let last_step = rand_below(&mut rng, l_steps);
                    let base_pos = rand_below(&mut rng, s - last_step);
                    let n = lyr * k_paths * l_steps * h * dh;
                    let rows = rand_vec(&mut rng, n);
                    let vrows = rand_vec(&mut rng, n);
                    lanes[li]
                        .paged
                        .commit_rollout_rows(&rows, &vrows, k_paths, l_steps, branch, last_step, base_pos);
                    lanes[li]
                        .shadow
                        .commit_rollout_rows(&rows, &vrows, k_paths, l_steps, branch, last_step, base_pos);
                    lanes[li].defined[base_pos..=base_pos + last_step].fill(true);
                }
                // tree-row commit
                4 if !lanes.is_empty() => {
                    let li = rand_below(&mut rng, lanes.len());
                    let nb = 1 + rand_below(&mut rng, 4);
                    let node = rand_below(&mut rng, nb);
                    let pos = rand_below(&mut rng, s);
                    let rows = rand_vec(&mut rng, lyr * nb * h * dh);
                    let vrows = rand_vec(&mut rng, lyr * nb * h * dh);
                    lanes[li].paged.commit_tree_row(&rows, &vrows, nb, node, pos);
                    lanes[li].shadow.commit_tree_row(&rows, &vrows, nb, node, pos);
                    lanes[li].defined[pos] = true;
                }
                // copy-on-write fork into a new lane
                5 if !lanes.is_empty() && lanes.len() < max_lanes => {
                    let li = rand_below(&mut rng, lanes.len());
                    let rows = rand_below(&mut rng, s + 4); // may exceed max_seq
                    let src = &lanes[li];
                    let forked = Lane {
                        paged: src.paged.clone_prefix(rows),
                        shadow: src.shadow.clone_prefix(rows),
                        defined: (0..s).map(|p| p < rows && src.defined[p]).collect(),
                    };
                    lanes.push(forked);
                }
                // prefix refresh of one lane from another (or itself — skip)
                6 if lanes.len() >= 2 => {
                    let li = rand_below(&mut rng, lanes.len());
                    let si = rand_below(&mut rng, lanes.len());
                    if li != si {
                        let rows = rand_below(&mut rng, s + 4);
                        let (dst, src) = if li < si {
                            let (a, b) = lanes.split_at_mut(si);
                            (&mut a[li], &b[0])
                        } else {
                            let (a, b) = lanes.split_at_mut(li);
                            (&mut b[0], &a[si])
                        };
                        dst.paged.copy_prefix_from(&src.paged, rows);
                        dst.shadow.copy_prefix_from(&src.shadow, rows);
                        dst.defined =
                            (0..s).map(|p| p < rows && src.defined[p]).collect();
                    }
                }
                // retire a lane: its blocks must come back to the free list
                _ => {
                    if !lanes.is_empty() {
                        let li = rand_below(&mut rng, lanes.len());
                        lanes.swap_remove(li);
                    }
                }
            }
            check_all(&pool, &lanes, &d, &ctx);
        }

        // drain: retiring every lane returns every block
        lanes.clear();
        pool.validate().unwrap_or_else(|e| panic!("seq {seq} drain: {e}"));
        assert_eq!(pool.live_blocks(), 0, "seq {seq}: blocks leaked past retirement");
        assert_eq!(
            pool.free_blocks(),
            pool.created(),
            "seq {seq}: free list must hold every created block after drain"
        );
    }
}

/// One warm lane plus its cold oracle: the shadows commit every row from
/// scratch, while the paged pair adopts whatever the cache matched and only
/// commits the tail.
struct WarmLane {
    tokens: Vec<u32>,
    target: KvCache,
    draft: KvCache,
    t_shadow: ContiguousKv,
    d_shadow: ContiguousKv,
}

/// Deterministic committed-row content, a pure function of (position,
/// token, role salt) — the property the real engine's backend consistency
/// contract provides, and the reason a cached block is interchangeable with
/// a cold prefill of the same tokens.
fn role_row(d: &ModelDims, tok: u32, pos: usize, salt: f32) -> (Vec<f32>, Vec<f32>) {
    let n = d.n_layers * d.n_heads * d.d_head;
    let k: Vec<f32> =
        (0..n).map(|e| salt + tok as f32 * 100.0 + (pos * n + e) as f32 * 0.5).collect();
    let v: Vec<f32> = k.iter().map(|x| -x + salt).collect();
    (k, v)
}

/// A token sequence that, most of the time, extends a prefix of an earlier
/// sequence — so the fuzz actually produces shared prefixes for the cache
/// to hit, split and evict.
fn gen_tokens(rng: &mut Pcg64, history: &[Vec<u32>], max_len: usize) -> Vec<u32> {
    let len = 1 + rand_below(rng, max_len);
    let mut t: Vec<u32> = Vec::new();
    if !history.is_empty() && rand_below(rng, 4) > 0 {
        let src = &history[rand_below(rng, history.len())];
        t.extend_from_slice(&src[..rand_below(rng, src.len().min(len) + 1)]);
    }
    while t.len() < len {
        t.push(rand_below(rng, 23) as u32);
    }
    t
}

fn check_warm_lane(lane: &WarmLane, d: &ModelDims, ctx: &str) {
    for pos in 0..lane.tokens.len() {
        for l in 0..d.n_layers {
            for hh in 0..d.n_heads {
                let (pk, pv) = lane.target.read_row(l, hh, pos);
                let (sk, sv) = lane.t_shadow.row(l, hh, pos);
                assert_eq!(pk, sk, "{ctx}: warm target K != cold l={l} h={hh} pos={pos}");
                assert_eq!(pv, sv, "{ctx}: warm target V != cold l={l} h={hh} pos={pos}");
                let (pk, pv) = lane.draft.read_row(l, hh, pos);
                let (sk, sv) = lane.d_shadow.row(l, hh, pos);
                assert_eq!(pk, sk, "{ctx}: warm draft K != cold l={l} h={hh} pos={pos}");
                assert_eq!(pv, sv, "{ctx}: warm draft V != cold l={l} h={hh} pos={pos}");
            }
        }
    }
}

/// Random interleavings of prefix-cache ops across lanes sharing two pools.
/// Every op preserves block conservation in both pools and bitwise equality
/// of every warm lane with its cold shadow; dropping all lanes leaves the
/// pools holding exactly the cached pairs, and dropping the cache drains
/// them to zero.
#[test]
fn fuzz_prefix_cache_insert_match_evict_interleavings() {
    let seqs: usize = std::env::var("SPECDELAY_FUZZ_SEQS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(1000);
    let ops_per_seq = 24usize;
    let max_lanes = 4usize;
    let max_len = 20usize;

    for seq in 0..seqs as u64 {
        let d = if seq % 2 == 0 {
            ModelDims { n_layers: 1, d_model: 4, n_heads: 2, d_head: 2, vocab: 7, max_seq: 24 }
        } else {
            ModelDims { n_layers: 2, d_model: 4, n_heads: 1, d_head: 3, vocab: 7, max_seq: 24 }
        };
        let bt = [1usize, 2, 4, 8][(seq % 4) as usize];
        let tp = BlockPool::new(d, bt, None);
        let dp = BlockPool::new(d, bt, None);
        let mut cache = PrefixCache::new(&tp, &dp);
        let mut rng = Pcg64::new(0xCA5E, seq);
        let mut lanes: Vec<WarmLane> = Vec::new();
        let mut history: Vec<Vec<u32>> = Vec::new();
        let (mut lookups, mut matched_total) = (0u64, 0u64);

        for op in 0..ops_per_seq {
            let ctx = format!("seq {seq} op {op} (bt {bt})");
            match rand_below(&mut rng, 8) {
                // admit a warm lane: match, adopt, commit only the tail
                0 | 1 | 2 if lanes.len() < max_lanes => {
                    let tokens = gen_tokens(&mut rng, &history, max_len);
                    let mut target = KvCache::paged(&tp);
                    let mut draft = KvCache::paged(&dp);
                    let matched = cache.match_into(&tokens, &mut target, &mut draft);
                    lookups += 1;
                    matched_total += matched as u64;
                    assert_eq!(matched % bt, 0, "{ctx}: partial-block match");
                    assert!(matched <= tokens.len(), "{ctx}: matched past the prompt");
                    let mut t_shadow = ContiguousKv::new(d);
                    let mut d_shadow = ContiguousKv::new(d);
                    for (pos, &tok) in tokens.iter().enumerate() {
                        let (tk, tv) = role_row(&d, tok, pos, 1.0);
                        let (dk, dv) = role_row(&d, tok, pos, 2.0);
                        if pos >= matched {
                            target.commit_row(&tk, &tv, pos);
                            draft.commit_row(&dk, &dv, pos);
                        }
                        t_shadow.commit_row(&tk, &tv, pos);
                        d_shadow.commit_row(&dk, &dv, pos);
                    }
                    history.push(tokens.clone());
                    lanes.push(WarmLane { tokens, target, draft, t_shadow, d_shadow });
                }
                // retire a lane into the cache (then sometimes drop it)
                3 | 4 if !lanes.is_empty() => {
                    let li = rand_below(&mut rng, lanes.len());
                    let lane = &lanes[li];
                    let plen = rand_below(&mut rng, lane.tokens.len() + 1);
                    cache.insert(
                        &lane.tokens[..plen],
                        lane.target.as_paged().unwrap(),
                        lane.draft.as_paged().unwrap(),
                    );
                    if rand_below(&mut rng, 2) == 0 {
                        lanes.swap_remove(li);
                    }
                }
                // budget pressure: evict some reclaimable pairs
                5 => {
                    let want = rand_below(&mut rng, 5);
                    let freed = cache.reclaim(want);
                    assert!(freed <= want, "{ctx}: reclaim overshot");
                }
                // full flush
                6 => cache.clear(),
                // drop a lane without caching it
                _ => {
                    if !lanes.is_empty() {
                        let li = rand_below(&mut rng, lanes.len());
                        lanes.swap_remove(li);
                    }
                }
            }
            tp.validate().unwrap_or_else(|e| panic!("{ctx}: target {e}"));
            dp.validate().unwrap_or_else(|e| panic!("{ctx}: draft {e}"));
            assert!(
                cache.reclaimable_pairs() <= cache.cached_pairs(),
                "{ctx}: reclaimable exceeds cached"
            );
            for lane in &lanes {
                check_warm_lane(lane, &d, &ctx);
            }
        }

        let c = cache.counters();
        assert_eq!(c.lookups, lookups, "seq {seq}: every paged admission is a lookup");
        assert_eq!(c.matched_rows, matched_total, "seq {seq}: adopted rows all accounted");
        assert!(c.hits <= c.lookups, "seq {seq}: hits bounded by lookups");

        // dropping every lane leaves exactly the cached pairs live...
        lanes.clear();
        tp.validate().unwrap_or_else(|e| panic!("seq {seq} post-lanes: {e}"));
        dp.validate().unwrap_or_else(|e| panic!("seq {seq} post-lanes: {e}"));
        let pairs = cache.cached_pairs();
        assert_eq!(tp.live_blocks(), pairs, "seq {seq}: target live != cached pairs");
        assert_eq!(dp.live_blocks(), pairs, "seq {seq}: draft live != cached pairs");
        // ...and dropping the cache drains both pools to zero
        drop(cache);
        for (role, pool) in [("target", &tp), ("draft", &dp)] {
            pool.validate().unwrap_or_else(|e| panic!("seq {seq} {role} post-cache: {e}"));
            assert_eq!(pool.live_blocks(), 0, "seq {seq}: {role} blocks leaked by the cache");
            assert_eq!(
                pool.free_blocks(),
                pool.created(),
                "seq {seq}: {role} free list incomplete after cache drop"
            );
        }
    }
}
