//! Property-based fuzz of the paged KV cache against a contiguous shadow.
//!
//! Each seeded sequence drives a random interleaving of lane operations —
//! create, commit (row / prefill / rollout span / tree row), copy-on-write
//! fork (`clone_prefix`), prefix refresh (`copy_prefix_from`), retire —
//! over several lanes sharing one [`BlockPool`], applying every op
//! identically to a [`ContiguousKv`] shadow. After **every** op it
//! asserts:
//!
//! * allocator invariants via [`BlockPool::validate`]: block conservation
//!   (`created == free + live`, i.e. no block is ever lost or
//!   double-freed) and that free-list blocks are referenced by nothing
//!   (refcount conservation — a retired block can never be read or forked);
//! * pool/lane accounting: unique live blocks bounded by the lanes' table
//!   residency (sharing can only reduce, never grow, the unique count);
//! * **bitwise read equality** with the shadow on every row both
//!   representations define (rows invalidated by a prefix op are excluded
//!   on both sides — the shared "must not be read" contract).
//!
//! The sequence count (default 1000, the acceptance floor) is tunable via
//! `SPECDELAY_FUZZ_SEQS`.

use specdelay::kvcache::{BlockPool, ContiguousKv, KvCache};
use specdelay::runtime::ModelDims;
use specdelay::util::Pcg64;

struct Lane {
    paged: KvCache,
    shadow: ContiguousKv,
    /// Rows both representations hold defined (written since the last
    /// prefix op that invalidated them).
    defined: Vec<bool>,
}

fn rand_vec(rng: &mut Pcg64, n: usize) -> Vec<f32> {
    (0..n).map(|_| rng.next_f32() * 8.0 - 4.0).collect()
}

fn rand_below(rng: &mut Pcg64, n: usize) -> usize {
    (rng.next_f32() as f64 * n as f64) as usize % n.max(1)
}

fn check_lane(lane: &Lane, d: &ModelDims, ctx: &str) {
    assert_eq!(lane.paged.len(), lane.shadow.len, "{ctx}: len diverged");
    for (pos, &def) in lane.defined.iter().enumerate() {
        if !def {
            continue;
        }
        for l in 0..d.n_layers {
            for hh in 0..d.n_heads {
                let (pk, pv) = lane.paged.read_row(l, hh, pos);
                let (sk, sv) = lane.shadow.row(l, hh, pos);
                assert_eq!(pk, sk, "{ctx}: K row diverged l={l} h={hh} pos={pos}");
                assert_eq!(pv, sv, "{ctx}: V row diverged l={l} h={hh} pos={pos}");
            }
        }
    }
}

fn check_all(pool: &BlockPool, lanes: &[Lane], d: &ModelDims, ctx: &str) {
    pool.validate().unwrap_or_else(|e| panic!("{ctx}: {e}"));
    let resident: usize = lanes
        .iter()
        .map(|l| l.paged.as_paged().unwrap().resident_blocks())
        .sum();
    let max_resident = lanes
        .iter()
        .map(|l| l.paged.as_paged().unwrap().resident_blocks())
        .max()
        .unwrap_or(0);
    let live = pool.live_blocks();
    assert!(live <= resident, "{ctx}: live {live} > table refs {resident}");
    assert!(live >= max_resident, "{ctx}: live {live} < widest lane {max_resident}");
    for lane in lanes {
        check_lane(lane, d, ctx);
    }
}

#[test]
fn fuzz_alloc_fork_write_retire_against_contiguous_shadow() {
    let seqs: usize = std::env::var("SPECDELAY_FUZZ_SEQS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(1000);
    let ops_per_seq = 30usize;
    let max_lanes = 5usize;

    for seq in 0..seqs as u64 {
        // alternate shapes: multi-head vs the single-head span-copy path
        let d = if seq % 2 == 0 {
            ModelDims { n_layers: 1, d_model: 4, n_heads: 2, d_head: 2, vocab: 7, max_seq: 24 }
        } else {
            ModelDims { n_layers: 2, d_model: 4, n_heads: 1, d_head: 3, vocab: 7, max_seq: 24 }
        };
        let bt = [1usize, 3, 5, 8][(seq % 4) as usize];
        let pool = BlockPool::new(d, bt, None);
        let mut rng = Pcg64::new(0xFA22, seq);
        let mut lanes: Vec<Lane> = Vec::new();
        let (lyr, h, dh, s) = (d.n_layers, d.n_heads, d.d_head, d.max_seq);

        for op in 0..ops_per_seq {
            let ctx = format!("seq {seq} op {op} (bt {bt})");
            let choice = rand_below(&mut rng, 8);
            match choice {
                // create a fresh empty lane
                0 => {
                    if lanes.len() < max_lanes {
                        lanes.push(Lane {
                            paged: KvCache::paged(&pool),
                            shadow: ContiguousKv::new(d),
                            defined: vec![false; s],
                        });
                    }
                }
                // single-row commit
                1 if !lanes.is_empty() => {
                    let li = rand_below(&mut rng, lanes.len());
                    let pos = rand_below(&mut rng, s);
                    let row = rand_vec(&mut rng, lyr * h * dh);
                    let vrow = rand_vec(&mut rng, lyr * h * dh);
                    lanes[li].paged.commit_row(&row, &vrow, pos);
                    lanes[li].shadow.commit_row(&row, &vrow, pos);
                    lanes[li].defined[pos] = true;
                }
                // prefill commit
                2 if !lanes.is_empty() => {
                    let li = rand_below(&mut rng, lanes.len());
                    let len = 1 + rand_below(&mut rng, s.min(12));
                    let s_pre = len + rand_below(&mut rng, 4);
                    let rows = rand_vec(&mut rng, lyr * h * s_pre * dh);
                    let vrows = rand_vec(&mut rng, lyr * h * s_pre * dh);
                    lanes[li].paged.commit_prefill(&rows, &vrows, s_pre, len);
                    lanes[li].shadow.commit_prefill(&rows, &vrows, s_pre, len);
                    lanes[li].defined[..len].fill(true);
                }
                // rollout span commit (exercises the per-block coalescing)
                3 if !lanes.is_empty() => {
                    let li = rand_below(&mut rng, lanes.len());
                    let k_paths = 1 + rand_below(&mut rng, 3);
                    let l_steps = 1 + rand_below(&mut rng, 4);
                    let branch = rand_below(&mut rng, k_paths);
                    let last_step = rand_below(&mut rng, l_steps);
                    let base_pos = rand_below(&mut rng, s - last_step);
                    let n = lyr * k_paths * l_steps * h * dh;
                    let rows = rand_vec(&mut rng, n);
                    let vrows = rand_vec(&mut rng, n);
                    lanes[li]
                        .paged
                        .commit_rollout_rows(&rows, &vrows, k_paths, l_steps, branch, last_step, base_pos);
                    lanes[li]
                        .shadow
                        .commit_rollout_rows(&rows, &vrows, k_paths, l_steps, branch, last_step, base_pos);
                    lanes[li].defined[base_pos..=base_pos + last_step].fill(true);
                }
                // tree-row commit
                4 if !lanes.is_empty() => {
                    let li = rand_below(&mut rng, lanes.len());
                    let nb = 1 + rand_below(&mut rng, 4);
                    let node = rand_below(&mut rng, nb);
                    let pos = rand_below(&mut rng, s);
                    let rows = rand_vec(&mut rng, lyr * nb * h * dh);
                    let vrows = rand_vec(&mut rng, lyr * nb * h * dh);
                    lanes[li].paged.commit_tree_row(&rows, &vrows, nb, node, pos);
                    lanes[li].shadow.commit_tree_row(&rows, &vrows, nb, node, pos);
                    lanes[li].defined[pos] = true;
                }
                // copy-on-write fork into a new lane
                5 if !lanes.is_empty() && lanes.len() < max_lanes => {
                    let li = rand_below(&mut rng, lanes.len());
                    let rows = rand_below(&mut rng, s + 4); // may exceed max_seq
                    let src = &lanes[li];
                    let forked = Lane {
                        paged: src.paged.clone_prefix(rows),
                        shadow: src.shadow.clone_prefix(rows),
                        defined: (0..s).map(|p| p < rows && src.defined[p]).collect(),
                    };
                    lanes.push(forked);
                }
                // prefix refresh of one lane from another (or itself — skip)
                6 if lanes.len() >= 2 => {
                    let li = rand_below(&mut rng, lanes.len());
                    let si = rand_below(&mut rng, lanes.len());
                    if li != si {
                        let rows = rand_below(&mut rng, s + 4);
                        let (dst, src) = if li < si {
                            let (a, b) = lanes.split_at_mut(si);
                            (&mut a[li], &b[0])
                        } else {
                            let (a, b) = lanes.split_at_mut(li);
                            (&mut b[0], &a[si])
                        };
                        dst.paged.copy_prefix_from(&src.paged, rows);
                        dst.shadow.copy_prefix_from(&src.shadow, rows);
                        dst.defined =
                            (0..s).map(|p| p < rows && src.defined[p]).collect();
                    }
                }
                // retire a lane: its blocks must come back to the free list
                _ => {
                    if !lanes.is_empty() {
                        let li = rand_below(&mut rng, lanes.len());
                        lanes.swap_remove(li);
                    }
                }
            }
            check_all(&pool, &lanes, &d, &ctx);
        }

        // drain: retiring every lane returns every block
        lanes.clear();
        pool.validate().unwrap_or_else(|e| panic!("seq {seq} drain: {e}"));
        assert_eq!(pool.live_blocks(), 0, "seq {seq}: blocks leaked past retirement");
        assert_eq!(
            pool.free_blocks(),
            pool.created(),
            "seq {seq}: free list must hold every created block after drain"
        );
    }
}
