//! Monte-Carlo losslessness validation for every verification algorithm.
//!
//! Losslessness is the non-negotiable invariant of speculative decoding: the
//! emitted token stream must follow the target chain exactly. We validate it
//! the only way it can be validated — empirically, over a toy language model
//! with exactly known conditionals:
//!
//!   * the FIRST emitted token of a block must follow p(.|root) exactly;
//!   * conditioned on the first i emitted tokens, token i+1 (when the block
//!     is long enough) must follow p(.|prefix) exactly
//!     (blocks that ended earlier regenerate the suffix from a fresh block,
//!     so the within-block conditional must itself match the target).
//!
//! This is the same style of validation the paper reports for its
//! acceptance/branching calculators ("empirically confirmed ... with Monte
//! Carlo sampling").
//!
//! On top of the tolerance checks, a chi-square goodness-of-fit pass
//! (shared machinery in `common::mc`, sample count env-tunable via
//! `SPECDELAY_MC_SAMPLES`) validates the first/second-token conditionals
//! of real `SpecEngine::step` blocks on the CPU reference backend for all
//! eight verifiers, under **both** KV storages (`SPECDELAY_PAGED_KV`
//! off/on equivalents), and asserts the two storages produce *identical*
//! tallies — the statistical and the bit-exactness halves of the paged
//! cache's losslessness contract.

mod common;

use specdelay::dist::Dist;
use specdelay::tree::{DraftTree, PathDraws, Provenance};
use specdelay::util::Pcg64;
use specdelay::verify::{all_verifiers, Verifier};

const V: usize = 4;

/// Toy LM: deterministic conditional distributions derived from a context
/// hash. `smooth` mixes toward uniform so ratios p/q stay bounded.
struct ToyLm {
    seed: u64,
    smooth: f32,
}

impl ToyLm {
    fn dist(&self, ctx: &[u32]) -> Dist {
        let mut h = Pcg64::new(
            self.seed ^ ctx.iter().fold(0xabcdu64, |a, &t| {
                a.wrapping_mul(31).wrapping_add(t as u64 + 1)
            }),
            77,
        );
        let mut v: Vec<f32> = (0..V).map(|_| h.next_f32() + 0.05).collect();
        let s: f32 = v.iter().sum();
        for x in v.iter_mut() {
            *x /= s;
        }
        for x in v.iter_mut() {
            *x = (1.0 - self.smooth) * *x + self.smooth / V as f32;
        }
        Dist(v)
    }
}

/// Draft a (K, L1, L2)-delayed tree from the toy draft model.
fn draft_delayed(
    p_lm: &ToyLm,
    q_lm: &ToyLm,
    root: &[u32],
    k: usize,
    l1: usize,
    l2: usize,
    rng: &mut Pcg64,
) -> DraftTree {
    let mut tree = DraftTree::new(*root.last().unwrap());
    let mut ctx: Vec<u32> = root.to_vec();
    let mut node = 0usize;
    // trunk
    for step in 0..l1 {
        let q = q_lm.dist(&ctx);
        let tok = q.sample(rng) as u32;
        tree.set_q(node, q);
        node = tree.add_child(node, tok, Provenance::Trunk { step });
        ctx.push(tok);
    }
    let trunk_end = node;
    let trunk_ctx = ctx.clone();
    let trunk_path: Vec<usize> = tree.path_nodes(trunk_end);
    // branches
    let mut paths = Vec::new();
    if l2 == 0 {
        if !trunk_path.is_empty() {
            paths.push(trunk_path.clone());
        }
    } else {
        for b in 0..k {
            let mut node = trunk_end;
            let mut ctx = trunk_ctx.clone();
            for step in 0..l2 {
                let q = q_lm.dist(&ctx);
                let tok = q.sample(rng) as u32;
                if tree.nodes[node].q.is_none() {
                    tree.set_q(node, q);
                }
                node = tree.add_child(node, tok, Provenance::Branch { branch: b, step });
                ctx.push(tok);
            }
            paths.push(tree.path_nodes(node));
        }
    }
    tree.path_draws = Some(PathDraws { paths, shared_edges: l1 });
    // target dists at every node
    for i in 0..tree.len() {
        let mut ctx = root[..root.len() - 1].to_vec();
        ctx.push(tree.nodes[0].token);
        ctx.extend(tree.path_tokens(i));
        tree.set_p(i, p_lm.dist(&ctx));
    }
    tree
}

/// Run `n` verification rounds and check emitted-stream conditionals against
/// the exact toy target chain up to depth `max_check`. `sparse` converts
/// every tree to sparse storage before verifying (the satellite rerun of
/// this suite with the sparse representation).
fn check_lossless_storage(
    verifier: &dyn Verifier,
    k: usize,
    l1: usize,
    l2: usize,
    seed: u64,
    sparse: bool,
) {
    let p_lm = ToyLm { seed: 1111, smooth: 0.2 };
    let q_lm = ToyLm { seed: 2222, smooth: 0.4 };
    let root = vec![1u32, 2];
    // full strength by default; SPECDELAY_MC_SAMPLES lets CI smoke cheaply
    let n = common::mc::mc_samples(60_000);
    let max_check = 3usize;

    let mut rng = Pcg64::seeded(seed);
    // counts[prefix as Vec<u32>] -> [token counts; V]
    use std::collections::HashMap;
    let mut counts: HashMap<Vec<u32>, Vec<usize>> = HashMap::new();

    for _ in 0..n {
        let mut tree = draft_delayed(&p_lm, &q_lm, &root, k, l1, l2, &mut rng);
        if sparse {
            tree = common::sparsify_tree(&tree);
        }
        let v = verifier.verify(&tree, &mut rng);
        let mut emitted: Vec<u32> =
            v.accepted.iter().map(|&i| tree.nodes[i].token).collect();
        emitted.push(v.correction);
        for d in 0..emitted.len().min(max_check) {
            let prefix = emitted[..d].to_vec();
            counts.entry(prefix).or_insert_with(|| vec![0; V])[emitted[d] as usize] += 1;
        }
    }

    for (prefix, cnt) in &counts {
        let total: usize = cnt.iter().sum();
        if total < 3000 {
            continue; // not enough conditional mass to test tightly
        }
        let mut ctx = root.clone();
        ctx.extend(prefix);
        let target = p_lm.dist(&ctx);
        common::mc::check_counts(
            &format!("{} prefix {prefix:?}", verifier.name()),
            cnt,
            &target.0,
            total,
            0.004,
        );
    }
}

fn check_lossless(verifier: &dyn Verifier, k: usize, l1: usize, l2: usize, seed: u64) {
    check_lossless_storage(verifier, k, l1, l2, seed, false)
}

#[test]
fn lossless_multipath_all_verifiers() {
    for v in all_verifiers() {
        // i.i.d. multipath: K=3 paths of length 2 from the root
        check_lossless(v.as_ref(), 3, 0, 2, 42);
    }
}

#[test]
fn lossless_delayed_tree_all_verifiers() {
    for v in all_verifiers() {
        // delayed expansion: trunk 2, then K=2 branches of length 2
        check_lossless(v.as_ref(), 2, 2, 2, 43);
    }
}

#[test]
fn lossless_single_path_all_verifiers() {
    for v in all_verifiers() {
        // pure single path (trunk only)
        check_lossless(v.as_ref(), 1, 3, 0, 44);
    }
}

/// The sparse representation must be just as lossless: same Monte-Carlo
/// validation over sparse-stored trees (delayed-expansion config).
#[test]
fn lossless_delayed_tree_all_verifiers_sparse_storage() {
    for v in all_verifiers() {
        check_lossless_storage(v.as_ref(), 2, 2, 2, 45, true);
    }
}

/// Chi-square goodness-of-fit upgrade of the Monte-Carlo validation, on
/// the *real* serving stack instead of synthetic trees: replay
/// `SpecEngine::step` blocks on the CPU reference backend and test the
/// first-token counts (and the dominant second-token conditionals)
/// against the backend's exact target conditionals, for **every drafter**
/// (delayed, root, greedy) × all eight verifiers under both KV storages.
/// The per-storage tallies must also be *identical* per drafter — the
/// bit-exactness contract of the paged cache means the statistical pass
/// cannot even in principle diverge between storages.
#[test]
fn chi_square_block_conditionals_all_drafters_verifiers_both_kv_storages() {
    use specdelay::coordinator::SpecEngine;
    use specdelay::dist::SamplingConfig;
    use specdelay::draft::{Action, DrafterKind};
    use specdelay::kvcache::KvStorage;
    use specdelay::runtime::{Backend, CpuModelConfig, CpuRefBackend, Role};

    let backend = CpuRefBackend::new(&CpuModelConfig::tiny(), 3);
    let sampling = SamplingConfig::new(0.5, 0.9);
    let v = backend.dims(Role::Target).vocab;
    let n = common::mc::mc_samples(800);
    let p_floor = 1e-6;

    for (di, drafter) in DrafterKind::ALL.into_iter().enumerate() {
        // one tally set per storage: [verifier][storage]
        let mut per_storage: Vec<Vec<common::mc::BlockConditionals>> = Vec::new();
        for storage in [KvStorage::Contiguous, KvStorage::Paged] {
            let spec = SpecEngine::new(&backend, sampling)
                .with_kv_storage(storage)
                .with_drafter(drafter);
            let base = spec.start("7+5= ").unwrap();
            // exact first-token conditional p(.|prompt)
            let toks_i32: Vec<i32> = base.tokens.iter().map(|&t| t as i32).collect();
            let pre = backend.prefill(Role::Target, &toks_i32, base.prompt_len).unwrap();
            let p0 = Dist::from_logits(&pre.logits, sampling);

            let mut tallies = Vec::new();
            for (vi, verifier) in specdelay::verify::all_verifiers().into_iter().enumerate() {
                let name = format!("{}/{}", drafter.name(), verifier.name());
                let t = common::mc::replay_block_conditionals(
                    &spec,
                    &base,
                    verifier.as_ref(),
                    Action::new(2, 1, 1),
                    v,
                    n,
                    0xC511 + (di * 100 + vi) as u64,
                );
                common::mc::assert_chi_square(
                    &format!("{name} first-token ({storage:?})"),
                    &t.first,
                    &p0.0,
                    n,
                    p_floor,
                );
                for (t1, c) in &t.second {
                    let total: usize = c.iter().sum();
                    if total < 250 {
                        continue; // too little conditional mass for a GOF test
                    }
                    let d = backend
                        .decode(Role::Target, base.target_kv.view(), *t1, base.prompt_len)
                        .unwrap();
                    let p1 = Dist::from_logits(&d.logits, sampling);
                    common::mc::assert_chi_square(
                        &format!("{name} second-token|{t1} ({storage:?})"),
                        c,
                        &p1.0,
                        total,
                        p_floor,
                    );
                }
                tallies.push(t);
            }
            per_storage.push(tallies);
        }

        // bit-exactness: identical seeds + bit-identical storages ⇒
        // identical emitted streams ⇒ identical tallies. Only the f32
        // dtype is a bit-exact drop-in; when CI selects a quantized pool
        // via SPECDELAY_KV_DTYPE the statistical halves above still must
        // pass, but paged tallies legitimately differ from contiguous.
        if specdelay::kvcache::KvDtype::global() != specdelay::kvcache::KvDtype::F32 {
            continue;
        }
        let (cont, paged) = (&per_storage[0], &per_storage[1]);
        for (i, (a, b)) in cont.iter().zip(paged).enumerate() {
            assert_eq!(
                a.first, b.first,
                "{drafter:?} verifier #{i}: first-token tallies diverge across storages"
            );
            assert_eq!(
                a.second, b.second,
                "{drafter:?} verifier #{i}: second-token tallies diverge across storages"
            );
        }
    }
}

/// The (backend × KV element precision) losslessness matrix: replay real
/// `SpecEngine::step` blocks for every verifier on both always-built CPU
/// backends (scalar reference and f32x8 SIMD) over paged pools of every
/// [`KvDtype`](specdelay::kvcache::KvDtype), and chi-square the
/// first/second-token conditionals against the *same backend's* exact
/// conditionals computed over the *same pools*. Quantization changes the
/// committed-prefix bytes, not the sampling identity: the engine's tree
/// pass and the oracle `decode` read identical (dequantized) rows, so
/// every cell must pass at full statistical strength. The f32 cells must
/// additionally produce tallies *identical* to contiguous storage — the
/// drop-in bit-exactness rung of the determinism ladder.
#[test]
fn chi_square_block_conditionals_backends_by_kv_dtype() {
    use specdelay::coordinator::{KvPools, SpecEngine};
    use specdelay::dist::SamplingConfig;
    use specdelay::draft::Action;
    use specdelay::kvcache::{BlockPool, KvDtype, KvStorage};
    use specdelay::runtime::{Backend, CpuModelConfig, CpuRefBackend, CpuSimdBackend, Role};

    let cfg = CpuModelConfig::tiny();
    let backends: Vec<Box<dyn Backend>> =
        vec![Box::new(CpuRefBackend::new(&cfg, 3)), Box::new(CpuSimdBackend::new(&cfg, 3))];
    let sampling = SamplingConfig::new(0.5, 0.9);
    let n = common::mc::mc_samples(600);
    let p_floor = 1e-6;
    let action = Action::new(2, 1, 1);

    for (bi, backend) in backends.iter().enumerate() {
        let backend = backend.as_ref();
        let v = backend.dims(Role::Target).vocab;
        // contiguous tallies on the same seeds: the oracle the f32 paged
        // cells must reproduce bit-for-bit
        let cont = SpecEngine::new(backend, sampling).with_kv_storage(KvStorage::Contiguous);
        let cont_base = cont.start("7+5= ").unwrap();
        let cont_tallies: Vec<common::mc::BlockConditionals> = specdelay::verify::all_verifiers()
            .into_iter()
            .enumerate()
            .map(|(vi, verifier)| {
                common::mc::replay_block_conditionals(
                    &cont,
                    &cont_base,
                    verifier.as_ref(),
                    action,
                    v,
                    n,
                    0xD7E0 + (bi * 1000 + vi) as u64,
                )
            })
            .collect();

        for dtype in [KvDtype::F32, KvDtype::F16, KvDtype::Int8] {
            // block size 4 splits the prompt prefix across blocks in every
            // cell; per-pool dtype keeps the matrix in one process
            let pools = KvPools {
                target: BlockPool::with_dtype(backend.dims(Role::Target), 4, None, dtype),
                draft: BlockPool::with_dtype(backend.dims(Role::Draft), 4, None, dtype),
            };
            let spec = SpecEngine::new(backend, sampling).with_kv_pools(pools);
            let base = spec.start("7+5= ").unwrap();
            // exact first-token conditional: the prefill dist (in-flight
            // rows, no cache reads — identical across dtypes)
            let toks_i32: Vec<i32> = base.tokens.iter().map(|&t| t as i32).collect();
            let pre = backend.prefill(Role::Target, &toks_i32, base.prompt_len).unwrap();
            let p0 = Dist::from_logits(&pre.logits, sampling);

            for (vi, verifier) in specdelay::verify::all_verifiers().into_iter().enumerate() {
                let name =
                    format!("{}/{}/{}", backend.name(), dtype.name(), verifier.name());
                let t = common::mc::replay_block_conditionals(
                    &spec,
                    &base,
                    verifier.as_ref(),
                    action,
                    v,
                    n,
                    0xD7E0 + (bi * 1000 + vi) as u64,
                );
                common::mc::assert_chi_square(
                    &format!("{name} first-token"),
                    &t.first,
                    &p0.0,
                    n,
                    p_floor,
                );
                for (t1, c) in &t.second {
                    let total: usize = c.iter().sum();
                    if total < 250 {
                        continue; // too little conditional mass for a GOF test
                    }
                    // exact second-token conditional over the *same*
                    // (possibly quantized) committed prefix the engine read
                    let d = backend
                        .decode(Role::Target, base.target_kv.view(), *t1, base.prompt_len)
                        .unwrap();
                    let p1 = Dist::from_logits(&d.logits, sampling);
                    common::mc::assert_chi_square(
                        &format!("{name} second-token|{t1}"),
                        c,
                        &p1.0,
                        total,
                        p_floor,
                    );
                }
                if dtype == KvDtype::F32 {
                    assert_eq!(
                        t.first, cont_tallies[vi].first,
                        "{name}: f32 paged first-token tallies diverge from contiguous"
                    );
                    assert_eq!(
                        t.second, cont_tallies[vi].second,
                        "{name}: f32 paged second-token tallies diverge from contiguous"
                    );
                }
            }
        }
    }
}

/// Traversal must accept at least as much as BV on single paths and more on
/// trees (the paper's headline structural finding).
#[test]
fn traversal_dominates_on_trees() {
    let p_lm = ToyLm { seed: 1111, smooth: 0.2 };
    let q_lm = ToyLm { seed: 2222, smooth: 0.4 };
    let root = vec![1u32, 2];
    let trav = specdelay::verify::verifier("Traversal").unwrap();
    let spec = specdelay::verify::verifier("SpecInfer").unwrap();
    let mut rng = Pcg64::seeded(7);
    let n = 20_000;
    let (mut t_sum, mut s_sum) = (0usize, 0usize);
    for _ in 0..n {
        let tree = draft_delayed(&p_lm, &q_lm, &root, 3, 0, 3, &mut rng);
        t_sum += trav.verify(&tree, &mut rng).tau();
        s_sum += spec.verify(&tree, &mut rng).tau();
    }
    let (t_avg, s_avg) = (t_sum as f64 / n as f64, s_sum as f64 / n as f64);
    assert!(
        t_avg > s_avg * 0.97,
        "Traversal {t_avg:.3} should be at least comparable to SpecInfer {s_avg:.3}"
    );
}
