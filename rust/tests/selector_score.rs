//! Equality and determinism guarantees for the shared-branching Eq. 3
//! scorer and the data-parallel layer:
//!
//! * the shared scorer's Ê table matches the frozen per-action scorer to
//!   1e-12 on seeded superset samples (all five OT solvers);
//! * `par_map_init` results are bit-identical to the serial path for every
//!   worker count, both on a synthetic rng workload and on real superset
//!   scoring with per-worker `ScoreScratch` arenas;
//! * the `_into` scratch variants replay their allocating wrappers exactly.

mod common;

use common::superset::{make_superset, ot_solvers};
use common::make_tree;
use specdelay::selector::{
    action_space, score_superset, score_superset_into, score_superset_per_action, ScoreScratch,
    Superset,
};
use specdelay::util::threadpool::par_map_init;
use specdelay::util::Pcg64;
use specdelay::verify::{expected_accepted, expected_accepted_into, Eq3Scratch};

fn seeded_supersets(n: usize, vocab: usize, seed: u64) -> Vec<Superset> {
    let mut rng = Pcg64::seeded(seed);
    (0..n).map(|_| make_superset(&mut rng, vocab)).collect()
}

#[test]
fn shared_scorer_matches_frozen_per_action_scorer() {
    let solvers = ot_solvers();
    let n_actions = action_space().len();
    for (case, ss) in seeded_supersets(2, 40, 0x5c0e).iter().enumerate() {
        let legacy = score_superset_per_action(ss, &solvers);
        let shared = score_superset(ss, &solvers);
        assert_eq!(legacy.len(), solvers.len());
        assert_eq!(shared.len(), solvers.len());
        for (si, (l_row, s_row)) in legacy.iter().zip(&shared).enumerate() {
            assert_eq!(l_row.len(), n_actions);
            assert_eq!(s_row.len(), n_actions);
            for (ai, (&l, &s)) in l_row.iter().zip(s_row).enumerate() {
                assert!(
                    (l - s).abs() <= 1e-12,
                    "case {case} solver {} action {ai}: per-action {l} vs shared {s}",
                    solvers[si].0
                );
            }
        }
    }
}

/// A warm scratch arena must not leak state between samples: scoring the
/// same sample with a cold and a heavily reused arena is bit-identical.
#[test]
fn score_scratch_reuse_is_stateless() {
    let solvers = ot_solvers();
    let supersets = seeded_supersets(3, 40, 0xA3);
    let mut warm = ScoreScratch::default();
    let mut table = Vec::new();
    for ss in &supersets {
        score_superset_into(ss, &solvers, &mut warm, &mut table);
    }
    // warm arena, re-scored in reverse order, vs a cold arena each time
    for ss in supersets.iter().rev() {
        score_superset_into(ss, &solvers, &mut warm, &mut table);
        let cold = score_superset(ss, &solvers);
        assert_eq!(table, cold);
    }
}

#[test]
fn parallel_superset_scoring_bit_identical_to_serial() {
    let solvers = ot_solvers();
    let score_all = |workers: usize| -> Vec<Vec<Vec<f64>>> {
        par_map_init(
            seeded_supersets(6, 32, 0xBB),
            workers,
            ScoreScratch::default,
            |scratch, _i, ss| {
                let mut table = Vec::new();
                score_superset_into(&ss, &solvers, scratch, &mut table);
                table
            },
        )
    };
    let serial = score_all(1);
    assert_eq!(serial.len(), 6);
    for workers in [2, 3, 8] {
        assert_eq!(serial, score_all(workers), "workers = {workers}");
    }
}

#[test]
fn expected_accepted_into_replays_allocating_wrapper() {
    let mut rng = Pcg64::seeded(0xEA);
    let mut scratch = Eq3Scratch::default();
    for case in 0..4 {
        let tree = make_tree(&mut rng, 64);
        for (name, solver) in ot_solvers() {
            let a = expected_accepted(&tree, solver.as_ref());
            let b = expected_accepted_into(&tree, solver.as_ref(), &mut scratch);
            let c = expected_accepted_into(&tree, solver.as_ref(), &mut scratch);
            assert_eq!(a, b, "case {case} {name}: cold scratch");
            assert_eq!(b, c, "case {case} {name}: warm scratch");
            assert!(a.is_finite() && a >= 0.0, "case {case} {name}: {a}");
        }
    }
}
