//! Integration tests over the real AOT artifacts: load HLO + weights via
//! PJRT, run prefill/decode/rollout/tree passes, and exercise the full
//! speculative decoding loop. Requires `make artifacts` (skipped otherwise).

use std::path::Path;

use specdelay::coordinator::{generate_autoregressive, FixedPolicy, SpecEngine};
use specdelay::dist::{Dist, SamplingConfig};
use specdelay::draft::Action;
use specdelay::runtime::{Backend, Engine, Role};
use specdelay::util::Pcg64;
use specdelay::verify;

fn artifacts() -> Option<&'static Path> {
    let p = Path::new("artifacts/qwen-sim");
    if p.join("meta.json").exists() {
        Some(p)
    } else {
        eprintln!("artifacts missing; run `make artifacts` first");
        None
    }
}

#[test]
fn prefill_decode_consistency() {
    let Some(dir) = artifacts() else { return };
    let engine = Engine::load(dir).unwrap();
    let toks: Vec<i32> = "Q: 3 + 4 = ? A:".bytes().map(|b| b as i32).collect();
    let len = toks.len();
    let out = engine.prefill(Role::Target, &toks, len).unwrap();
    assert_eq!(out.logits.len(), engine.meta.target.vocab);

    // iterated decode must reproduce the prefill logits at the last token
    let mut kv = specdelay::kvcache::KvCache::new(engine.meta.target);
    let mut last = None;
    for (i, &t) in toks.iter().enumerate() {
        let d = Backend::decode(&engine, Role::Target, kv.view(), t as u32, i).unwrap();
        kv.commit_row(&d.k_row, &d.v_row, i);
        last = Some(d.logits);
    }
    let last = last.unwrap();
    let max_diff = out
        .logits
        .iter()
        .zip(&last)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f32, f32::max);
    assert!(max_diff < 2e-3, "prefill vs decode logits diverge: {max_diff}");
}

#[test]
fn rollout_dists_match_decode() {
    let Some(dir) = artifacts() else { return };
    let engine = Engine::load(dir).unwrap();
    let toks: Vec<i32> = "story: the quiet river ".bytes().map(|b| b as i32).collect();
    let len = toks.len();
    let pre = engine.prefill(Role::Draft, &toks, len).unwrap();
    let mut kv = specdelay::kvcache::KvCache::new(engine.meta.draft);
    kv.commit_prefill(&pre.k_rows, &pre.v_rows, engine.meta.s_pre, len);

    let root = toks[len - 1] as u32;
    // rollout step 0 dist must equal the decode dist at the root
    let uni = vec![0.5f32; 2];
    let ro = Backend::rollout(&engine, 1, 2, kv.view(), root, len - 1, &uni, 1.0, 1.0).unwrap();
    let de = Backend::decode(&engine, Role::Draft, kv.view(), root, len - 1).unwrap();
    let v = engine.meta.draft.vocab;
    let q_ro = &ro.dists[..v];
    let q_de = Dist::from_logits(&de.logits, SamplingConfig::new(1.0, 1.0));
    let max_diff = q_ro
        .iter()
        .zip(&q_de.0)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f32, f32::max);
    assert!(max_diff < 1e-4, "rollout vs decode q diverge: {max_diff}");
}

#[test]
fn spec_generation_runs_and_accepts() {
    let Some(dir) = artifacts() else { return };
    let engine = Engine::load(dir).unwrap();
    let sampling = SamplingConfig::new(0.6, 1.0);
    let spec = SpecEngine::new(&engine, sampling);
    let verifier = verify::verifier("SpecInfer").unwrap();
    let mut rng = Pcg64::seeded(17);
    let (text, stats) = spec
        .generate(
            "Q: 12 * 3 = ? A:",
            48,
            verifier.as_ref(),
            &FixedPolicy(Action::new(2, 2, 4)),
            &mut rng,
        )
        .unwrap();
    assert!(stats.tokens > 0, "no tokens generated");
    assert!(stats.block_efficiency() >= 1.0);
    assert!(!text.is_empty());

    // autoregressive baseline still works; speculation must accept tokens
    let mut rng2 = Pcg64::seeded(18);
    let (_t2, s2) =
        generate_autoregressive(&engine, sampling, "Q: 12 * 3 = ? A:", 24, &mut rng2).unwrap();
    assert!(s2.tokens > 0);
    assert!(
        stats.block_efficiency() > 1.2,
        "speculation should accept tokens (got {:.2})",
        stats.block_efficiency()
    );
}

#[test]
fn all_verifiers_run_on_real_model() {
    let Some(dir) = artifacts() else { return };
    let engine = Engine::load(dir).unwrap();
    let sampling = SamplingConfig::new(0.8, 1.0);
    let spec = SpecEngine::new(&engine, sampling);
    for name in ["NSS", "Naive", "NaiveTree", "SpecTr", "SpecInfer", "Khisti", "BV", "Traversal"]
    {
        let verifier = verify::verifier(name).unwrap();
        let action = if name == "Naive" || name == "BV" {
            Action::new(1, 4, 0)
        } else {
            Action::new(2, 1, 3)
        };
        let mut rng = Pcg64::seeded(99);
        let (_text, stats) = spec
            .generate(
                "translate en->fr: the sea => ",
                24,
                verifier.as_ref(),
                &FixedPolicy(action),
                &mut rng,
            )
            .unwrap();
        assert!(stats.tokens > 0, "{name}: no tokens");
        assert!(
            stats.block_efficiency() >= 1.0,
            "{name}: block efficiency {}",
            stats.block_efficiency()
        );
    }
}
