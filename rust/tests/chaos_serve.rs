//! Seeded chaos suite for the resilient serving loop.
//!
//! A [`FaultyBackend`] wraps the CPU reference backend with a seeded,
//! content-addressed fault plan — transient dispatch failures, NaN-corrupted
//! sampled surfaces, latency spikes — and the suite drives
//! [`ServeLoop`] through it, asserting the recovery layer's contracts:
//!
//! * completed non-degraded streams are **bit-identical** to the fault-free
//!   oracle, for every batch size × worker count × KV storage swept;
//! * every injected fault is **retried or surfaced**, never silently
//!   dropped (`FaultStats` vs `RecoveryCounters` accounting closes);
//! * failing and panicking lanes never leak paged KV blocks (pool
//!   `validate()`, zero live blocks, `free == created` after the drain);
//! * degraded autoregressive fallback stays **lossless in distribution**
//!   (chi-square against the exact target conditional);
//! * deadlines and panic isolation retire exactly the affected lanes.
//!
//! Sample counts follow `SPECDELAY_MC_SAMPLES`; `SPECDELAY_CHAOS_FAST=1`
//! shrinks the sweep matrix for CI smoke runs. Everything is seeded — a
//! failure reproduces exactly.

mod common;

use std::time::Duration;

use common::mc::{assert_chi_square, check_counts, mc_samples};
use specdelay::coordinator::{
    FixedPolicy, ResilienceConfig, SchedConfig, ServeError, ServeLoop, ServeRequest, SpecEngine,
};
use specdelay::dist::{Dist, SamplingConfig};
use specdelay::draft::Action;
use specdelay::kvcache::{KvRef, KvStorage};
use specdelay::runtime::{
    Backend, CpuModelConfig, CpuRefBackend, DecodeOut, FamilyMeta, FaultOp, FaultPlan,
    FaultyBackend, PrefillOut, Role, RolloutOut, TreeOut,
};
use specdelay::tokenizer;
use specdelay::util::Pcg64;
use specdelay::verify;

const PROMPTS: [&str; 6] = ["12*3= ", "9-4= ", "1,2,3,", "(5+5)/2= ", "0.5*8= ", "77+1= "];

fn fast() -> bool {
    std::env::var("SPECDELAY_CHAOS_FAST").is_ok_and(|v| v != "0" && !v.is_empty())
}

/// Resilience with retries but the health machine effectively disabled, so
/// every completed stream stays on the speculative (bit-identical) path.
fn retry_only() -> ResilienceConfig {
    ResilienceConfig {
        max_retries: 50,
        deadline: None,
        degrade_after: usize::MAX / 2,
        fail_after: usize::MAX / 2,
        probe_interval: 4,
    }
}

/// Fault-free oracle streams (text, tokens, blocks) per request id, from a
/// serial single-lane loop on contiguous storage.
fn oracle(
    backend: &dyn Backend,
    sampling: SamplingConfig,
    max_new: usize,
    seed: u64,
) -> Vec<(String, Vec<u32>, usize)> {
    let verifier = verify::verifier("SpecInfer").unwrap();
    let policy = FixedPolicy(Action::new(2, 2, 2));
    let mut srv = ServeLoop::new(backend, sampling, verifier.as_ref(), &policy, 1)
        .with_workers(1)
        .with_kv_storage(KvStorage::Contiguous);
    for p in &PROMPTS {
        srv.submit(ServeRequest::new(p.to_string(), max_new, seed));
    }
    srv.run()
        .unwrap()
        .into_iter()
        .map(|o| {
            assert!(o.error.is_none(), "oracle lane {} failed: {:?}", o.id, o.error);
            (o.text, o.tokens, o.stats.blocks)
        })
        .collect()
}

/// Same plan + same seeds ⇒ same faults, same recoveries, same streams:
/// the injector is content-addressed and attempt-indexed, so the whole
/// chaotic run is reproducible bit-for-bit.
#[test]
fn faulty_serving_is_deterministic() {
    let inner = CpuRefBackend::new(&CpuModelConfig::tiny(), 4);
    let sampling = SamplingConfig::new(0.8, 0.95);
    let plan = FaultPlan::quiet(7).with_transient(0.05).with_corrupt(0.02);
    let fb = FaultyBackend::new(&inner, plan);
    let verifier = verify::verifier("SpecInfer").unwrap();
    let policy = FixedPolicy(Action::new(2, 2, 2));
    let mut runs = Vec::new();
    for _ in 0..2 {
        fb.reset();
        // one worker: dispatch arrival order is lane order, so even the
        // injector's per-signature attempt counters replay exactly (with
        // more workers, two lanes issuing byte-identical dispatch
        // signatures would race for attempt indices — see the
        // faulty-backend docs; stream equality across worker counts is
        // covered by the sweep test against the fault-free oracle)
        let mut srv = ServeLoop::new(&fb, sampling, verifier.as_ref(), &policy, 3)
            .with_workers(1)
            .with_resilience(retry_only());
        for p in &PROMPTS {
            srv.submit(ServeRequest::new(p.to_string(), 12, 5));
        }
        let outs = srv.run().unwrap();
        let summary: Vec<_> = outs
            .into_iter()
            .map(|o| (o.id, o.text, o.tokens, o.degraded, o.retries, o.error))
            .collect();
        runs.push((summary, fb.stats(), srv.recovery().clone()));
    }
    assert_eq!(runs[0].1, runs[1].1, "fault schedules diverged across identical runs");
    assert_eq!(runs[0].2, runs[1].2, "recovery counters diverged across identical runs");
    assert_eq!(runs[0].0, runs[1].0, "served streams diverged across identical runs");
}

/// The main sweep: fault rates × KV storages × batch sizes × worker counts.
/// Every request completes, every completed stream is bit-identical to the
/// fault-free oracle, the fault/recovery accounting closes, and no paged
/// block leaks.
#[test]
fn chaos_sweep_streams_bit_identical_and_faults_accounted() {
    let inner = CpuRefBackend::new(&CpuModelConfig::tiny(), 4);
    let sampling = SamplingConfig::new(0.8, 0.95);
    let verifier = verify::verifier("SpecInfer").unwrap();
    let policy = FixedPolicy(Action::new(2, 2, 2));
    let max_new = if fast() { 12 } else { 20 };
    let want = oracle(&inner, sampling, max_new, 1234);

    let rates: &[f64] = if fast() { &[0.02] } else { &[0.002, 0.02] };
    let batches: &[usize] = if fast() { &[3] } else { &[1, 3, 8] };
    let workerses: &[usize] = if fast() { &[4] } else { &[1, 4] };
    for &rate in rates {
        for storage in [KvStorage::Contiguous, KvStorage::Paged] {
            for &batch in batches {
                for &workers in workerses {
                    let plan = FaultPlan::quiet(0xC4A05)
                        .with_transient(rate)
                        .with_corrupt(rate / 2.0);
                    let fb = FaultyBackend::new(&inner, plan);
                    let mut srv =
                        ServeLoop::new(&fb, sampling, verifier.as_ref(), &policy, batch)
                            .with_workers(workers)
                            .with_kv_storage(storage)
                            .with_resilience(retry_only());
                    for p in &PROMPTS {
                        srv.submit(ServeRequest::new(p.to_string(), max_new, 1234));
                    }
                    let outs = srv.run().unwrap();
                    let ctx = format!(
                        "rate {rate} storage {storage:?} batch {batch} workers {workers}"
                    );
                    assert_eq!(outs.len(), PROMPTS.len(), "{ctx}");
                    for (o, (text, toks, blocks)) in outs.iter().zip(&want) {
                        assert!(o.error.is_none(), "{ctx}: lane {} failed: {:?}", o.id, o.error);
                        assert!(!o.degraded, "{ctx}: lane {} degraded unexpectedly", o.id);
                        assert_eq!(&o.text, text, "{ctx}: stream diverged (id {})", o.id);
                        assert_eq!(&o.tokens, toks, "{ctx}: token stream diverged (id {})", o.id);
                        assert_eq!(o.stats.blocks, *blocks, "{ctx}: block count diverged");
                    }
                    // accounting closes: injector-side faults == loop-side
                    // observations == retried + surfaced
                    let fs = fb.stats();
                    let rc = srv.recovery();
                    assert_eq!(
                        fs.transient + fs.corrupt,
                        rc.transient_seen + rc.corrupt_seen,
                        "{ctx}: loop missed injected faults"
                    );
                    assert_eq!(
                        rc.transient_seen + rc.corrupt_seen + rc.panics,
                        rc.retries + rc.surfaced,
                        "{ctx}: a fault was neither retried nor surfaced"
                    );
                    assert_eq!(rc.surfaced, 0, "{ctx}: no lane should exhaust at this rate");
                    srv.clear_prefix_cache(); // cache-held runs are not leaks
                    if let Some(pools) = srv.spec().kv_pools() {
                        for (role, pool) in [("target", &pools.target), ("draft", &pools.draft)] {
                            pool.validate().unwrap();
                            assert_eq!(pool.live_blocks(), 0, "{ctx}: {role} pool leaked");
                            assert_eq!(pool.free_blocks(), pool.created(), "{ctx}: {role} pool");
                        }
                    }
                }
            }
        }
    }
}

/// Checkpoint restores under a capped block budget: the doubled per-lane
/// reservation must keep the cap respected at its high-water mark while
/// streams stay bit-identical to the oracle.
#[test]
fn block_budget_cap_respected_under_faults() {
    let inner = CpuRefBackend::new(&CpuModelConfig::tiny(), 4);
    let sampling = SamplingConfig::new(0.8, 0.95);
    let verifier = verify::verifier("SpecInfer").unwrap();
    let policy = FixedPolicy(Action::new(2, 2, 2));
    let max_new = 12;
    let want = oracle(&inner, sampling, max_new, 77);

    let plan = FaultPlan::quiet(0xB10C).with_transient(0.03).with_corrupt(0.01);
    let fb = FaultyBackend::new(&inner, plan);
    let mut srv = ServeLoop::new(&fb, sampling, verifier.as_ref(), &policy, 4)
        .with_workers(2)
        .with_block_budget(2)
        .with_resilience(retry_only());
    for p in &PROMPTS {
        srv.submit(ServeRequest::new(p.to_string(), max_new, 77));
    }
    let outs = srv.run().unwrap();
    for (o, (text, toks, _)) in outs.iter().zip(&want) {
        assert!(o.error.is_none(), "lane {} failed: {:?}", o.id, o.error);
        assert_eq!(&o.text, text, "budgeted stream diverged (id {})", o.id);
        assert_eq!(&o.tokens, toks);
    }
    srv.clear_prefix_cache(); // cache-held runs are not leaks
    let pools = srv.spec().kv_pools().expect("block budget implies paged pools");
    for (role, pool) in [("target", &pools.target), ("draft", &pools.draft)] {
        pool.validate().unwrap();
        let cap = pool.max_blocks().unwrap();
        assert!(
            pool.peak_live_blocks() <= cap,
            "{role} pool exceeded its cap under faults: peak {} > {cap}",
            pool.peak_live_blocks()
        );
        assert_eq!(pool.live_blocks(), 0, "{role} pool leaked under faults");
        assert_eq!(pool.free_blocks(), pool.created(), "{role} pool free-list incomplete");
    }
}

/// Satellite regression: without any recovery configured, a lane that
/// errors mid-generation is dropped on the error path — its
/// partially-committed paged blocks must all return to the pool
/// (`created == free` after the drain). This is the lane-error block-leak
/// guard.
#[test]
fn lane_error_path_leaks_no_blocks() {
    let inner = CpuRefBackend::new(&CpuModelConfig::tiny(), 4);
    let sampling = SamplingConfig::new(0.8, 0.95);
    let verifier = verify::verifier("SpecInfer").unwrap();
    let policy = FixedPolicy(Action::new(2, 2, 2));
    let plan = FaultPlan::quiet(0xDEAD).with_transient(0.4).with_corrupt(0.2);
    let fb = FaultyBackend::new(&inner, plan);
    let mut srv = ServeLoop::new(&fb, sampling, verifier.as_ref(), &policy, 4)
        .with_workers(2)
        .with_kv_storage(KvStorage::Paged);
    for p in &PROMPTS {
        srv.submit(ServeRequest::new(p.to_string(), 16, 3));
    }
    let outs = srv.run().unwrap();
    assert_eq!(outs.len(), PROMPTS.len());
    let failed = outs.iter().filter(|o| o.error.is_some()).count();
    assert!(failed > 0, "fault rates this high must fail at least one lane");
    for o in &outs {
        if let Some(e) = &o.error {
            assert!(
                matches!(e, ServeError::Transient { .. } | ServeError::Corrupt { .. }),
                "unexpected error class without resilience: {e:?}"
            );
        }
    }
    let rc = srv.recovery();
    assert_eq!(rc.retries, 0, "no retries without resilience");
    assert_eq!(rc.surfaced, failed, "every fault must surface on an output");
    srv.clear_prefix_cache(); // cache-held runs are not leaks
    let pools = srv.spec().kv_pools().expect("paged storage has pools");
    for (role, pool) in [("target", &pools.target), ("draft", &pools.draft)] {
        pool.validate().unwrap();
        assert_eq!(pool.live_blocks(), 0, "{role} pool: error-path lane drop leaked blocks");
        assert_eq!(
            pool.free_blocks(),
            pool.created(),
            "{role} pool: free list must hold every created block after the drain"
        );
    }
}

/// Degraded-mode losslessness: with the speculative path permanently
/// faulting, the circuit breaker switches lanes to autoregressive decode.
/// The first emitted token of each request must follow the exact target
/// conditional p(·|prompt) — degraded throughput, identical distribution.
#[test]
fn degraded_mode_first_token_follows_target_conditional() {
    let inner = CpuRefBackend::new(&CpuModelConfig::tiny(), 3);
    let sampling = SamplingConfig::new(0.5, 0.9);
    let prompt = "7+5= ";

    // exact first-token conditional from the plain backend
    let spec = SpecEngine::new(&inner, sampling);
    let base = spec.start(prompt).unwrap();
    let toks_i32: Vec<i32> = base.tokens.iter().map(|&t| t as i32).collect();
    let pre = inner.prefill(Role::Target, &toks_i32, base.prompt_len).unwrap();
    let p0 = Dist::from_logits(&pre.logits, sampling);
    drop(base);

    // every speculative dispatch faults; prefill/decode stay clean
    let plan = FaultPlan::quiet(5)
        .with_transient(1.0)
        .with_ops(vec![FaultOp::Rollout, FaultOp::TreeVerify]);
    let fb = FaultyBackend::new(&inner, plan);
    let cfg = ResilienceConfig {
        max_retries: 4,
        deadline: None,
        degrade_after: 2,
        fail_after: usize::MAX / 2,
        probe_interval: 0, // pin degraded: every probe would fault anyway
    };
    let verifier = verify::verifier("SpecInfer").unwrap();
    let policy = FixedPolicy(Action::new(2, 2, 2));
    let n = mc_samples(600);
    let mut srv = ServeLoop::new(&fb, sampling, verifier.as_ref(), &policy, 8)
        .with_workers(4)
        .with_resilience(cfg);
    for _ in 0..n {
        srv.submit(ServeRequest::new(prompt.to_string(), 1, 0xC0FFEE));
    }
    let outs = srv.run().unwrap();
    assert_eq!(outs.len(), n);
    let v = inner.dims(Role::Target).vocab;
    let mut counts = vec![0usize; v];
    for o in &outs {
        assert!(o.error.is_none(), "lane {} failed: {:?}", o.id, o.error);
        assert!(o.degraded, "lane {} should be flagged degraded", o.id);
        assert_eq!(o.tokens.len(), 1, "lane {} emitted {} tokens", o.id, o.tokens.len());
        counts[o.tokens[0] as usize] += 1;
    }
    let rc = srv.recovery();
    assert!(rc.degraded_entered >= 1, "breaker never tripped: {rc:?}");
    assert!(rc.degraded_ticks > 0);
    check_counts("degraded first-token", &counts, &p0.0, n, 0.005);
    assert_chi_square("degraded first-token", &counts, &p0.0, n, 1e-3);
}

/// Per-request deadlines: a latency-spiking backend makes every tick slow;
/// lanes must retire with `ServeError::Deadline` and partial streams
/// instead of holding the batch hostage.
#[test]
fn deadline_retires_straggling_lanes() {
    let inner = CpuRefBackend::new(&CpuModelConfig::tiny(), 4);
    let sampling = SamplingConfig::new(0.8, 0.95);
    let plan = FaultPlan::quiet(2).with_latency(1.0, Duration::from_millis(10));
    let fb = FaultyBackend::new(&inner, plan);
    let cfg = ResilienceConfig {
        max_retries: 50,
        deadline: Some(Duration::from_millis(2)),
        degrade_after: usize::MAX / 2,
        fail_after: usize::MAX / 2,
        probe_interval: 0,
    };
    let verifier = verify::verifier("SpecInfer").unwrap();
    let policy = FixedPolicy(Action::new(2, 2, 2));
    let mut srv = ServeLoop::new(&fb, sampling, verifier.as_ref(), &policy, 3)
        .with_workers(1)
        .with_resilience(cfg);
    for p in &PROMPTS[..3] {
        srv.submit(ServeRequest::new(p.to_string(), 64, 9));
    }
    let outs = srv.run().unwrap();
    assert_eq!(outs.len(), 3);
    for o in &outs {
        match &o.error {
            Some(ServeError::Deadline { elapsed_secs }) => {
                assert!(*elapsed_secs >= 0.002, "deadline fired early: {elapsed_secs}");
            }
            other => panic!("lane {} should retire by deadline, got {other:?}", o.id),
        }
    }
    assert_eq!(srv.recovery().deadline_retired, 3);
    assert!(fb.stats().latency > 0, "latency spikes never fired");
}

/// A backend wrapper that panics on one specific prompt's prefill —
/// modelling a poisoned request rather than a flaky backend.
struct PanickyBackend<'a> {
    inner: &'a dyn Backend,
    trip: Vec<i32>,
}

impl Backend for PanickyBackend<'_> {
    fn meta(&self) -> &FamilyMeta {
        self.inner.meta()
    }
    fn name(&self) -> &'static str {
        "panicky"
    }
    fn prefill(&self, role: Role, tokens: &[i32], length: usize) -> anyhow::Result<PrefillOut> {
        if tokens[..length] == self.trip[..] {
            panic!("injected prefill panic");
        }
        self.inner.prefill(role, tokens, length)
    }
    fn decode(&self, role: Role, kv: KvRef<'_>, token: u32, pos: usize) -> anyhow::Result<DecodeOut> {
        self.inner.decode(role, kv, token, pos)
    }
    #[allow(clippy::too_many_arguments)]
    fn rollout(
        &self,
        k: usize,
        l: usize,
        kv: KvRef<'_>,
        token: u32,
        pos: usize,
        uniforms: &[f32],
        temperature: f32,
        top_p: f32,
    ) -> anyhow::Result<RolloutOut> {
        self.inner.rollout(k, l, kv, token, pos, uniforms, temperature, top_p)
    }
    #[allow(clippy::too_many_arguments)]
    fn tree_verify(
        &self,
        n_bucket: usize,
        kv: KvRef<'_>,
        tokens: &[i32],
        positions: &[i32],
        bias: &[f32],
        cache_len: usize,
    ) -> anyhow::Result<TreeOut> {
        self.inner.tree_verify(n_bucket, kv, tokens, positions, bias, cache_len)
    }
}

/// Panic isolation: one lane's tick panics; that lane retires as
/// `ServeError::Panic`, every other lane's stream is bit-identical to the
/// oracle, and nothing leaks.
#[test]
fn lane_panic_is_isolated_from_the_batch() {
    let inner = CpuRefBackend::new(&CpuModelConfig::tiny(), 4);
    let sampling = SamplingConfig::new(0.8, 0.95);
    let max_new = 12;
    let want = oracle(&inner, sampling, max_new, 21);

    let poisoned = 2usize; // PROMPTS[2] panics at prefill
    let trip: Vec<i32> = tokenizer::encode(PROMPTS[poisoned]).iter().map(|&t| t as i32).collect();
    let pb = PanickyBackend { inner: &inner, trip };
    let verifier = verify::verifier("SpecInfer").unwrap();
    let policy = FixedPolicy(Action::new(2, 2, 2));
    let mut srv = ServeLoop::new(&pb, sampling, verifier.as_ref(), &policy, 3)
        .with_workers(2)
        .with_kv_storage(KvStorage::Paged);
    for p in &PROMPTS {
        srv.submit(ServeRequest::new(p.to_string(), max_new, 21));
    }
    let outs = srv.run().unwrap();
    assert_eq!(outs.len(), PROMPTS.len());
    for (i, (o, (text, toks, _))) in outs.iter().zip(&want).enumerate() {
        if i == poisoned {
            match &o.error {
                Some(ServeError::Panic { message }) => {
                    assert!(message.contains("injected prefill panic"), "{message}");
                }
                other => panic!("poisoned lane should retire as Panic, got {other:?}"),
            }
        } else {
            assert!(o.error.is_none(), "healthy lane {} failed: {:?}", o.id, o.error);
            assert_eq!(&o.text, text, "healthy lane {} diverged beside a panic", o.id);
            assert_eq!(&o.tokens, toks);
        }
    }
    assert_eq!(srv.recovery().panics, 1);
    srv.clear_prefix_cache(); // cache-held runs are not leaks
    let pools = srv.spec().kv_pools().expect("paged storage has pools");
    for (role, pool) in [("target", &pools.target), ("draft", &pools.draft)] {
        pool.validate().unwrap();
        assert_eq!(pool.live_blocks(), 0, "{role} pool leaked beside a panic");
    }
}

/// Resilience must be a no-op on a healthy backend: identical streams to
/// the plain loop, zero recovery activity, zero checkpoint-induced drift.
#[test]
fn fault_free_resilience_is_identity() {
    let inner = CpuRefBackend::new(&CpuModelConfig::tiny(), 4);
    let sampling = SamplingConfig::new(0.8, 0.95);
    let verifier = verify::verifier("SpecInfer").unwrap();
    let policy = FixedPolicy(Action::new(2, 2, 2));
    let max_new = 14;

    let mut plain = ServeLoop::new(&inner, sampling, verifier.as_ref(), &policy, 3)
        .with_workers(2)
        .with_kv_storage(KvStorage::Paged);
    let fb = FaultyBackend::new(&inner, FaultPlan::quiet(1));
    let mut resil = ServeLoop::new(&fb, sampling, verifier.as_ref(), &policy, 3)
        .with_workers(2)
        .with_kv_storage(KvStorage::Paged)
        .with_resilience(ResilienceConfig::default());
    for p in &PROMPTS {
        plain.submit(ServeRequest::new(p.to_string(), max_new, 42));
        resil.submit(ServeRequest::new(p.to_string(), max_new, 42));
    }
    let a = plain.run().unwrap();
    let b = resil.run().unwrap();
    assert_eq!(a.len(), b.len());
    for (x, y) in a.iter().zip(&b) {
        assert!(x.error.is_none() && y.error.is_none());
        assert_eq!(x.text, y.text, "resilience changed a fault-free stream (id {})", x.id);
        assert_eq!(x.tokens, y.tokens);
        assert_eq!(x.stats.blocks, y.stats.blocks);
        assert!(!y.degraded);
        assert_eq!(y.retries, 0);
    }
    let fs = fb.stats();
    assert!(fs.dispatches > 0);
    assert_eq!(fs.transient + fs.corrupt + fs.latency, 0, "quiet plan injected something");
    assert_eq!(
        *resil.recovery(),
        Default::default(),
        "fault-free run must report zero recovery activity"
    );
    resil.clear_prefix_cache(); // cache-held runs are not leaks
    let pools = resil.spec().kv_pools().expect("paged storage has pools");
    for (role, pool) in [("target", &pools.target), ("draft", &pools.draft)] {
        pool.validate().unwrap();
        assert_eq!(pool.live_blocks(), 0, "{role} pool leaked with checkpoints on");
    }
}

/// Scheduler × fault interaction: chunked prefill, preemption and context
/// rebuild must compose with the recovery layer — every PR-6 invariant
/// (bit-identical completed streams, closed fault accounting, zero block
/// leaks) holds while a tiny block pool forces lanes to park and resume
/// under an active fault injector.
#[test]
fn scheduler_preserves_fault_invariants_under_preemption() {
    let inner = CpuRefBackend::new(&CpuModelConfig::tiny(), 4);
    let sampling = SamplingConfig::new(0.8, 0.95);
    let verifier = verify::verifier("SpecInfer").unwrap();
    let policy = FixedPolicy(Action::new(2, 2, 2));
    let max_new = if fast() { 12 } else { 20 };
    let want = oracle(&inner, sampling, max_new, 2026);

    let plan = FaultPlan::quiet(0x5C4ED).with_transient(0.02).with_corrupt(0.01);
    let fb = FaultyBackend::new(&inner, plan);
    // budget 1 clamps the pools to the single-lane worst case, so four
    // batch slots guarantee pool pressure: lanes park, resume, and (under
    // sustained pressure) rebuild their context by chunked replay — all
    // while faults restore checkpoints or force full restarts
    let mut srv = ServeLoop::new(&fb, sampling, verifier.as_ref(), &policy, 4)
        .with_block_budget(1)
        .with_resilience(retry_only())
        .with_scheduler(SchedConfig { prefill_chunk: 4, ..SchedConfig::default() });
    for p in &PROMPTS {
        srv.submit(ServeRequest::new(p.to_string(), max_new, 2026));
    }
    let outs = srv.run().unwrap();
    assert_eq!(outs.len(), PROMPTS.len());
    for (o, (text, toks, blocks)) in outs.iter().zip(&want) {
        assert!(o.error.is_none(), "lane {} failed under sched+faults: {:?}", o.id, o.error);
        assert!(!o.degraded, "lane {} degraded unexpectedly", o.id);
        assert_eq!(&o.text, text, "sched+fault stream diverged (id {})", o.id);
        assert_eq!(&o.tokens, toks, "sched+fault token stream diverged (id {})", o.id);
        assert_eq!(o.stats.blocks, *blocks, "sched+fault block count diverged (id {})", o.id);
    }
    let sc = srv.sched_counters().clone();
    assert!(sc.preempted >= 1, "tiny pool must force preemption: {sc:?}");
    assert!(sc.resumed >= sc.preempted, "every parked lane resumes: {sc:?}");
    assert!(sc.prefill_chunks >= PROMPTS.len(), "chunked prefill never engaged: {sc:?}");
    // fault accounting still closes with the scheduler in the loop
    let fs = fb.stats();
    let rc = srv.recovery();
    assert_eq!(
        fs.transient + fs.corrupt,
        rc.transient_seen + rc.corrupt_seen,
        "loop missed injected faults under the scheduler"
    );
    assert_eq!(
        rc.transient_seen + rc.corrupt_seen + rc.panics,
        rc.retries + rc.surfaced,
        "a fault was neither retried nor surfaced under the scheduler"
    );
    assert_eq!(rc.surfaced, 0, "no lane should exhaust at this rate");
    srv.clear_prefix_cache(); // cache-held runs are not leaks
    let pools = srv.spec().kv_pools().expect("block budget implies paged pools");
    for (role, pool) in [("target", &pools.target), ("draft", &pools.draft)] {
        pool.validate().unwrap();
        let cap = pool.max_blocks().unwrap();
        assert!(
            pool.peak_live_blocks() <= cap,
            "{role} pool exceeded its cap under sched+faults: peak {} > {cap}",
            pool.peak_live_blocks()
        );
        assert_eq!(pool.live_blocks(), 0, "{role} pool leaked under sched+faults");
        assert_eq!(pool.free_blocks(), pool.created(), "{role} pool free/created mismatch");
    }
}
