//! Acceptance suite for the preemptive priority scheduler
//! ([`ServeLoop::with_scheduler`]) — the overload-robustness layer on top
//! of the continuous-batching loop.
//!
//! The scheduler's one non-negotiable contract is **losslessness**: chunked
//! prefill, preempt-and-requeue, context release/rebuild, priorities and
//! weighted admission may change *when* work runs, but never *what* any
//! stream contains. Every test here pins a scheduler behaviour against the
//! serial [`SpecEngine::generate`] oracle on the same per-request rng
//! stream (`Pcg64::new(seed, id)`):
//!
//! * **Equality grid** — scheduler streams (with chunking forced on) are
//!   bit-identical to serial generation *and* to the FIFO loop across
//!   batch sizes × worker counts × KV storages;
//! * **Preemption** — a deliberately tiny block pool forces lanes to park,
//!   resume, and rebuild; streams stay bit-identical and the pools leak
//!   nothing;
//! * **Shedding** — expired deadlines and queue overflow retire requests
//!   as structured [`ServeError::Shed`] outputs with zero backend work,
//!   and the accounting closes: submitted == completed + shed;
//! * **Deadline granularity** — an expired lane retires within one prefill
//!   chunk of its deadline instead of finishing its generation first;
//! * **Tight reservations** — the FIFO loop's per-request block reserve is
//!   sized from `prompt + max_new + overshoot`, so a small pool admits
//!   short lanes concurrently instead of serialising on the whole-model
//!   worst case.

use std::time::Duration;

use specdelay::coordinator::{
    FixedPolicy, Priority, SchedConfig, ServeLoop, ServeRequest, SpecEngine,
};
use specdelay::dist::SamplingConfig;
use specdelay::draft::Action;
use specdelay::kvcache::{KvRef, KvStorage};
use specdelay::runtime::{
    Backend, CpuModelConfig, CpuRefBackend, DecodeOut, FamilyMeta, PrefillOut, Role, RolloutOut,
    TreeOut,
};
use specdelay::util::Pcg64;
use specdelay::verify;

const PROMPTS: [&str; 6] = ["12*3= ", "9-4= ", "1,2,3,", "(5+5)/2= ", "0.5*8= ", "77+1= "];

/// Serial per-request oracle: (text, tokens, blocks) for each prompt on
/// the contiguous reference path, rng stream `Pcg64::new(seed, id)` —
/// exactly what every `ServeLoop` mode must reproduce bit-for-bit.
fn serial_oracle(
    backend: &CpuRefBackend,
    sampling: SamplingConfig,
    verifier: &dyn specdelay::verify::Verifier,
    policy: &FixedPolicy,
    max_new: usize,
    seed: u64,
) -> Vec<(String, usize, usize)> {
    let spec = SpecEngine::new(backend, sampling).with_kv_storage(KvStorage::Contiguous);
    PROMPTS
        .iter()
        .enumerate()
        .map(|(id, p)| {
            let mut rng = Pcg64::new(seed, id as u64);
            let (text, stats) = spec.generate(p, max_new, verifier, policy, &mut rng).unwrap();
            (text, stats.tokens, stats.blocks)
        })
        .collect()
}

fn assert_pools_clean(srv: &mut ServeLoop<'_>, label: &str) {
    // under SPECDELAY_PREFIX_CACHE=1 the cache legitimately retains runs
    // past the drain — flush it so retained != leaked
    srv.clear_prefix_cache();
    if let Some(pools) = srv.spec().kv_pools() {
        for (role, pool) in [("target", &pools.target), ("draft", &pools.draft)] {
            pool.validate().unwrap();
            assert_eq!(pool.live_blocks(), 0, "{label}: {role} pool leaked blocks");
            assert_eq!(
                pool.free_blocks(),
                pool.created(),
                "{label}: {role} pool free/created mismatch"
            );
            if let Some(cap) = pool.max_blocks() {
                assert!(
                    pool.peak_live_blocks() <= cap,
                    "{label}: {role} pool exceeded its cap: peak {} > {cap}",
                    pool.peak_live_blocks()
                );
            }
        }
    }
}

/// The scheduler losslessness oracle: with chunked prefill engaged and
/// priorities mixed, every stream is bit-identical to serial generation
/// and to the FIFO loop, for every batch size × worker count × storage.
#[test]
fn scheduler_streams_match_serial_and_fifo() {
    let backend = CpuRefBackend::new(&CpuModelConfig::tiny(), 4);
    let sampling = SamplingConfig::new(0.8, 0.95);
    let verifier = verify::verifier("SpecInfer").unwrap();
    let policy = FixedPolicy(Action::new(2, 2, 2));
    let max_new = 24;
    let seed = 4321;
    let reference =
        serial_oracle(&backend, sampling, verifier.as_ref(), &policy, max_new, seed);
    let classes = [Priority::High, Priority::Normal, Priority::Low];

    for storage in [KvStorage::Contiguous, KvStorage::Paged] {
        for batch in [1usize, 3, 8] {
            for workers in [1usize, 4] {
                let label = format!("storage {storage:?} batch {batch} workers {workers}");
                let requests: Vec<ServeRequest> = PROMPTS
                    .iter()
                    .enumerate()
                    .map(|(i, p)| {
                        ServeRequest::new(p.to_string(), max_new, seed)
                            .with_priority(classes[i % classes.len()])
                    })
                    .collect();

                let mut fifo =
                    ServeLoop::new(&backend, sampling, verifier.as_ref(), &policy, batch)
                        .with_workers(workers)
                        .with_kv_storage(storage)
                        .without_scheduler();
                for r in &requests {
                    fifo.submit(r.clone());
                }
                let fifo_outs = fifo.run().unwrap();

                // chunk 3 is smaller than every prompt, so every lane
                // actually takes the multi-tick prefill path
                let mut srv =
                    ServeLoop::new(&backend, sampling, verifier.as_ref(), &policy, batch)
                        .with_workers(workers)
                        .with_kv_storage(storage)
                        .with_scheduler(SchedConfig {
                            prefill_chunk: 3,
                            ..SchedConfig::default()
                        });
                for r in &requests {
                    srv.submit(r.clone());
                }
                let outs = srv.run().unwrap();

                assert!(
                    srv.sched_counters().prefill_chunks >= 2 * PROMPTS.len(),
                    "{label}: chunked prefill never engaged"
                );
                assert_eq!(outs.len(), PROMPTS.len());
                for ((o, f), (text, tokens, blocks)) in
                    outs.iter().zip(&fifo_outs).zip(&reference)
                {
                    assert!(o.error.is_none(), "{label}: lane {} failed: {:?}", o.id, o.error);
                    assert!(f.error.is_none(), "{label}: FIFO lane {} failed: {:?}", f.id, f.error);
                    assert_eq!(&o.text, text, "{label}: scheduler diverged from serial (id {})", o.id);
                    assert_eq!(&f.text, text, "{label}: FIFO diverged from serial (id {})", f.id);
                    assert_eq!(o.tokens, f.tokens, "{label}: scheduler diverged from FIFO (id {})", o.id);
                    assert_eq!(o.stats.tokens, *tokens, "{label}: token count (id {})", o.id);
                    assert_eq!(o.stats.blocks, *blocks, "{label}: block count (id {})", o.id);
                    assert_eq!(o.priority, classes[o.id as usize % classes.len()]);
                }
                assert_pools_clean(&mut srv, &label);
            }
        }
    }
}

/// Overload under a deliberately tiny block pool: the scheduler must park
/// lanes (and, under sustained pressure, release their blocks entirely and
/// rebuild by chunked replay) — and every stream must still be
/// bit-identical to serial generation, with zero leaked blocks.
#[test]
fn preempted_lanes_resume_and_stay_bit_identical() {
    let backend = CpuRefBackend::new(&CpuModelConfig::tiny(), 4);
    let sampling = SamplingConfig::new(0.8, 0.95);
    let verifier = verify::verifier("Traversal").unwrap();
    let policy = FixedPolicy(Action::new(2, 2, 2));
    let max_new = 24;
    let seed = 777;
    let reference =
        serial_oracle(&backend, sampling, verifier.as_ref(), &policy, max_new, seed);

    // budget 1 clamps the pools to the single-lane worst case — the
    // smallest legal pool — while 4 batch slots keep admission eager, so
    // active lanes must fight over blocks
    let mut srv = ServeLoop::new(&backend, sampling, verifier.as_ref(), &policy, 4)
        .with_block_budget(1)
        .with_scheduler(SchedConfig { prefill_chunk: 4, ..SchedConfig::default() });
    for p in &PROMPTS {
        srv.submit(ServeRequest::new(p.to_string(), max_new, seed));
    }
    let outs = srv.run().unwrap();
    assert_eq!(srv.queued(), 0);
    assert_eq!(outs.len(), PROMPTS.len());

    let c = srv.sched_counters().clone();
    assert!(c.preempted >= 1, "tiny pool must force preemption: {c:?}");
    assert!(c.resumed >= 1, "parked lanes must be re-admitted: {c:?}");
    assert!(
        c.resumed >= c.preempted,
        "every preempted lane resumes (possibly after a release): {c:?}"
    );
    for (o, (text, tokens, blocks)) in outs.iter().zip(&reference) {
        assert!(o.error.is_none(), "lane {} failed under preemption: {:?}", o.id, o.error);
        assert_eq!(&o.text, text, "preempted stream diverged (id {})", o.id);
        assert_eq!(o.stats.tokens, *tokens);
        assert_eq!(o.stats.blocks, *blocks, "preemption must not change block count (id {})", o.id);
    }
    assert_pools_clean(&mut srv, "preemption");
}

/// Load shedding is structured and fully accounted: an expired-deadline
/// request and queue-overflow victims retire from the queue as
/// [`ServeError::Shed`] outputs (empty stream, no backend work), overflow
/// sheds lowest-priority-first, and submitted == completed + shed.
#[test]
fn shedding_is_structured_and_accounted() {
    let backend = CpuRefBackend::new(&CpuModelConfig::tiny(), 4);
    let sampling = SamplingConfig::new(0.8, 0.95);
    let verifier = verify::verifier("SpecInfer").unwrap();
    let policy = FixedPolicy(Action::new(2, 2, 2));
    let max_new = 16;
    let seed = 55;
    let reference =
        serial_oracle(&backend, sampling, verifier.as_ref(), &policy, max_new, seed);

    let mut srv = ServeLoop::new(&backend, sampling, verifier.as_ref(), &policy, 2)
        .with_scheduler(SchedConfig {
            prefill_chunk: 4,
            max_queue: Some(3),
            ..SchedConfig::default()
        });
    for (i, p) in PROMPTS.iter().enumerate() {
        let mut req = ServeRequest::new(p.to_string(), max_new, seed);
        if i == 2 {
            // already expired on arrival: must be shed, never dispatched
            req = req.with_deadline(Duration::ZERO);
        }
        if i == 5 {
            // the only low-priority request: overflow's first victim
            req = req.with_priority(Priority::Low);
        }
        srv.submit(req);
    }
    let outs = srv.run().unwrap();
    assert_eq!(srv.queued(), 0);
    assert_eq!(outs.len(), PROMPTS.len(), "every submitted request gets exactly one output");

    let shed: Vec<u64> = outs
        .iter()
        .filter(|o| o.error.as_ref().is_some_and(|e| e.kind() == "shed"))
        .map(|o| o.id)
        .collect();
    let completed: Vec<u64> =
        outs.iter().filter(|o| o.error.is_none()).map(|o| o.id).collect();
    // deadline sheds id 2; overflow (queued 5 > 3) sheds the low-priority
    // id 5 first, then the youngest normal id 4
    assert_eq!(shed, vec![2, 4, 5]);
    assert_eq!(completed, vec![0, 1, 3]);
    assert_eq!(srv.sched_counters().shed, shed.len());
    assert_eq!(completed.len() + shed.len(), PROMPTS.len(), "accounting must close");

    for o in &outs {
        if shed.contains(&o.id) {
            assert!(o.tokens.is_empty(), "shed lane {} ran backend work", o.id);
            assert!(o.ttft_secs.is_none());
            let msg = o.error.as_ref().unwrap().to_string();
            if o.id == 2 {
                assert!(msg.contains("deadline"), "id 2 shed reason: {msg}");
            } else {
                assert!(msg.contains("overflow"), "id {} shed reason: {msg}", o.id);
            }
        } else {
            let (text, tokens, _) = &reference[o.id as usize];
            assert_eq!(&o.text, text, "survivor stream diverged (id {})", o.id);
            assert_eq!(o.stats.tokens, *tokens);
        }
    }
}

/// A backend whose chunked-prefill entry point is slow — stands in for a
/// long-context prefill so the deadline-granularity contract is observable
/// on the tiny model.
struct SlowBackend {
    inner: CpuRefBackend,
    delay: Duration,
}

impl Backend for SlowBackend {
    fn meta(&self) -> &FamilyMeta {
        self.inner.meta()
    }
    fn name(&self) -> &'static str {
        "slow-prefill"
    }
    fn prefill(&self, role: Role, tokens: &[i32], length: usize) -> anyhow::Result<PrefillOut> {
        self.inner.prefill(role, tokens, length)
    }
    fn prefill_chunk(
        &self,
        role: Role,
        kv: KvRef<'_>,
        tokens: &[i32],
        start: usize,
        len: usize,
    ) -> anyhow::Result<PrefillOut> {
        std::thread::sleep(self.delay);
        self.inner.prefill_chunk(role, kv, tokens, start, len)
    }
    fn decode(&self, role: Role, kv: KvRef<'_>, token: u32, pos: usize) -> anyhow::Result<DecodeOut> {
        self.inner.decode(role, kv, token, pos)
    }
    #[allow(clippy::too_many_arguments)]
    fn rollout(
        &self,
        k: usize,
        l: usize,
        kv: KvRef<'_>,
        token: u32,
        pos: usize,
        uniforms: &[f32],
        temperature: f32,
        top_p: f32,
    ) -> anyhow::Result<RolloutOut> {
        self.inner.rollout(k, l, kv, token, pos, uniforms, temperature, top_p)
    }
    #[allow(clippy::too_many_arguments)]
    fn tree_verify(
        &self,
        n_bucket: usize,
        kv: KvRef<'_>,
        tokens: &[i32],
        positions: &[i32],
        bias: &[f32],
        cache_len: usize,
    ) -> anyhow::Result<TreeOut> {
        self.inner.tree_verify(n_bucket, kv, tokens, positions, bias, cache_len)
    }
}

/// Deadline granularity: with chunked prefill, an expired lane retires
/// before its *next* chunk is dispatched — a deadline shorter than the
/// full prefill yields a partial-prefill retirement, not a
/// whole-generation overrun.
#[test]
fn deadline_retires_within_one_chunk_of_expiry() {
    let slow = SlowBackend {
        inner: CpuRefBackend::new(&CpuModelConfig::tiny(), 4),
        delay: Duration::from_millis(5),
    };
    let sampling = SamplingConfig::new(0.8, 0.95);
    let verifier = verify::verifier("SpecInfer").unwrap();
    let policy = FixedPolicy(Action::new(2, 2, 2));
    // 20 prompt rows at chunk 1 and 5ms/chunk: the full prefill alone
    // takes ~100ms, far past the 12ms deadline
    let prompt = "1+2+3+4+5+6+7+8+9+0=";
    let rows = specdelay::tokenizer::encode(prompt).len();
    assert!(rows >= 16, "prompt must span many chunks (got {rows})");

    let mut srv = ServeLoop::new(&slow, sampling, verifier.as_ref(), &policy, 1)
        .with_scheduler(SchedConfig { prefill_chunk: 1, ..SchedConfig::default() });
    srv.submit(
        ServeRequest::new(prompt, 8, 9).with_deadline(Duration::from_millis(12)),
    );
    let outs = srv.run().unwrap();
    assert_eq!(outs.len(), 1);
    let o = &outs[0];
    assert_eq!(
        o.error.as_ref().map(|e| e.kind()),
        Some("deadline"),
        "expected a deadline retirement, got {:?}",
        o.error
    );
    assert!(o.tokens.is_empty(), "the lane never finished prefill, so nothing was emitted");

    let chunks = srv.sched_counters().prefill_chunks;
    assert!(chunks >= 1, "the deadline must expire mid-prefill, not before any work");
    assert!(
        chunks < rows,
        "lane must retire within a chunk of its deadline, not run the full {rows}-row \
         prefill (dispatched {chunks} chunks)"
    );
}

/// Tight per-request reservations (FIFO mode): a pool sized well below
/// `lanes × whole-model worst case` still admits short requests
/// concurrently, because the reserve is `prompt + max_new + overshoot`
/// rows — and the streams stay bit-identical to an uncapped run.
#[test]
fn tight_reservations_admit_short_lanes_concurrently() {
    let backend = CpuRefBackend::new(&CpuModelConfig::tiny(), 4);
    let sampling = SamplingConfig::new(0.8, 0.95);
    let verifier = verify::verifier("SpecInfer").unwrap();
    let policy = FixedPolicy(Action::new(2, 2, 2));
    let max_new = 8;
    let seed = 31;

    let mut free = ServeLoop::new(&backend, sampling, verifier.as_ref(), &policy, 4)
        .with_kv_storage(KvStorage::Paged)
        .without_scheduler();
    for p in &PROMPTS {
        free.submit(ServeRequest::new(p.to_string(), max_new, seed));
    }
    let want: Vec<String> = free.run().unwrap().into_iter().map(|o| o.text).collect();

    // 12 blocks: under the old whole-model reservation (the single-lane
    // worst case in *both* pools) this pool serialised lanes; the tight
    // `prompt + max_new + overshoot` reserve fits at least two short
    // lanes at once
    let mut srv = ServeLoop::new(&backend, sampling, verifier.as_ref(), &policy, 4)
        .with_block_budget(12)
        .without_scheduler();
    for p in &PROMPTS {
        srv.submit(ServeRequest::new(p.to_string(), max_new, seed));
    }
    let outs = srv.run().unwrap();
    assert_eq!(outs.len(), PROMPTS.len());
    assert!(
        srv.sched_counters().peak_active >= 2,
        "tight reservations must admit short lanes concurrently (peak {})",
        srv.sched_counters().peak_active
    );
    for (o, want_text) in outs.iter().zip(&want) {
        assert!(o.error.is_none(), "lane {} failed: {:?}", o.id, o.error);
        assert_eq!(&o.text, want_text, "capped stream diverged (id {})", o.id);
    }
    assert_pools_clean(&mut srv, "tight-reserve");
}
