//! Counting-allocator proof of the tentpole guarantee: a steady-state
//! (warm-scratch) `verify_into` call performs zero heap allocations, for
//! every verifier except the documented Khisti LP.
//!
//! Everything runs inside ONE #[test] so the process-global allocation
//! counter is never polluted by a concurrently running test thread. The
//! allocator and workload are shared with the `verify_hot` bench via
//! `tests/common/mod.rs`, so this test asserts exactly the configuration
//! the bench measures.

mod common;

use common::{
    allocs, make_greedy_tree, make_root_tree, make_topp_tree, make_tree, random_dist,
    sparsify_tree, CountingAlloc,
};
use specdelay::dist::{Dist, SparseDist};
use specdelay::tree::DraftTree;
use specdelay::util::Pcg64;
use specdelay::verify::{verifier, Verdict, VerifyScratch};

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

#[test]
fn steady_state_verify_is_allocation_free() {
    let vocab = 97;
    let mut rng = Pcg64::seeded(7);
    let trees: Vec<DraftTree> = (0..16).map(|_| make_tree(&mut rng, vocab)).collect();
    // Traversal fallback variant: no recorded draws (leaf paths rebuilt
    // into scratch each walk)
    let fallback_trees: Vec<DraftTree> = trees
        .iter()
        .map(|t| {
            let mut t = t.clone();
            t.path_draws = None;
            t
        })
        .collect();

    // Khisti's per-node transportation LP is the documented exception.
    let names = ["NSS", "Naive", "NaiveTree", "SpecTr", "SpecInfer", "BV", "Traversal"];
    let verifiers: Vec<_> = names.iter().map(|&n| (n, verifier(n).unwrap())).collect();

    let mut scratch = VerifyScratch::new();
    scratch.reserve(vocab, 16, 8);
    let mut verdict = Verdict::default();
    verdict.accepted.reserve(64);

    // Warm-up: every verifier over every tree, twice, so all scratch
    // buffers reach their high-water capacity before counting starts.
    for _ in 0..2 {
        for (_, ver) in &verifiers {
            for t in &trees {
                ver.verify_into(t, &mut rng, &mut scratch, &mut verdict);
            }
            for t in &fallback_trees {
                ver.verify_into(t, &mut rng, &mut scratch, &mut verdict);
            }
        }
    }

    for (name, ver) in &verifiers {
        let rounds = 200usize;
        let a0 = allocs();
        for i in 0..rounds {
            ver.verify_into(&trees[i % trees.len()], &mut rng, &mut scratch, &mut verdict);
        }
        let da = allocs() - a0;
        assert_eq!(
            da, 0,
            "{name}: {da} allocations across {rounds} steady-state verifies (expected 0)"
        );
        // verdicts must still be produced (the walk really ran)
        assert!(verdict.block_tokens() >= 1);
    }

    // Traversal's fallback (no recorded path draws) must also be free.
    let trav = &verifiers.iter().find(|(n, _)| *n == "Traversal").unwrap().1;
    let a0 = allocs();
    for i in 0..200 {
        trav.verify_into(
            &fallback_trees[i % fallback_trees.len()],
            &mut rng,
            &mut scratch,
            &mut verdict,
        );
    }
    assert_eq!(allocs() - a0, 0, "Traversal fallback path allocated");

    // ---- root / greedy drafter geometries ----
    // The same steady-state guarantee over the new drafters' tree shapes:
    // branches attached at the root, every path an independent draw
    // (`shared_edges = 0`), with the greedy shape mixing a root-started
    // trunk path into the draw list.
    let root_trees: Vec<DraftTree> = (0..16).map(|_| make_root_tree(&mut rng, vocab)).collect();
    let greedy_trees: Vec<DraftTree> =
        (0..16).map(|_| make_greedy_tree(&mut rng, vocab)).collect();
    for (geom, geom_trees) in [("root", &root_trees), ("greedy", &greedy_trees)] {
        for _ in 0..2 {
            for (_, ver) in &verifiers {
                for t in geom_trees {
                    ver.verify_into(t, &mut rng, &mut scratch, &mut verdict);
                }
            }
        }
        for (name, ver) in &verifiers {
            let rounds = 200usize;
            let a0 = allocs();
            for i in 0..rounds {
                ver.verify_into(
                    &geom_trees[i % geom_trees.len()],
                    &mut rng,
                    &mut scratch,
                    &mut verdict,
                );
            }
            let da = allocs() - a0;
            assert_eq!(
                da, 0,
                "{name} ({geom} drafter geometry): {da} allocations across {rounds} \
                 steady-state verifies (expected 0)"
            );
            assert!(verdict.block_tokens() >= 1);
        }
    }

    // And the core dist kernels themselves: sampling and scratch residuals.
    let p = random_dist(vocab, &mut rng, 2.0);
    let q = random_dist(vocab, &mut rng, 1.0);
    let mut buf = Dist::default();
    Dist::residual_into(&p, &q, &mut buf); // warm
    let a0 = allocs();
    for _ in 0..100 {
        let t = p.sample(&mut rng);
        assert!(t < vocab);
        Dist::residual_into(&p, &q, &mut buf);
    }
    assert_eq!(allocs() - a0, 0, "dist kernels allocated");

    // ---- sparse storage: the same guarantee with truncated supports ----
    // The first sparse walk flips the scratch buffers' representation
    // (one-off allocations); after the warm-up rounds every verifier must
    // again be allocation-free in steady state.
    let sparse_trees: Vec<DraftTree> = (0..16)
        .map(|_| sparsify_tree(&make_topp_tree(&mut rng, vocab, 0.9)))
        .collect();
    let sparse_fallback: Vec<DraftTree> = sparse_trees
        .iter()
        .map(|t| {
            let mut t = t.clone();
            t.path_draws = None;
            t
        })
        .collect();
    for _ in 0..2 {
        for (_, ver) in &verifiers {
            for t in &sparse_trees {
                ver.verify_into(t, &mut rng, &mut scratch, &mut verdict);
            }
            for t in &sparse_fallback {
                ver.verify_into(t, &mut rng, &mut scratch, &mut verdict);
            }
        }
    }
    for (name, ver) in &verifiers {
        let rounds = 200usize;
        let a0 = allocs();
        for i in 0..rounds {
            ver.verify_into(
                &sparse_trees[i % sparse_trees.len()],
                &mut rng,
                &mut scratch,
                &mut verdict,
            );
        }
        let da = allocs() - a0;
        assert_eq!(
            da, 0,
            "{name} (sparse): {da} allocations across {rounds} steady-state verifies"
        );
        assert!(verdict.block_tokens() >= 1);
    }
    let a0 = allocs();
    for i in 0..200 {
        trav.verify_into(
            &sparse_fallback[i % sparse_fallback.len()],
            &mut rng,
            &mut scratch,
            &mut verdict,
        );
    }
    assert_eq!(allocs() - a0, 0, "Traversal sparse fallback path allocated");

    // Sparse dist kernels: sampling and scratch residual merges.
    let ps = SparseDist::from_dense(&p);
    let qs = SparseDist::from_dense(&q);
    let mut sbuf = SparseDist::default();
    sbuf.ids.reserve(vocab);
    sbuf.ps.reserve(vocab);
    SparseDist::residual_into(&ps, &qs, &mut sbuf); // warm
    let a0 = allocs();
    for _ in 0..100 {
        let t = ps.sample(&mut rng);
        assert!(t < vocab);
        SparseDist::residual_into(&ps, &qs, &mut sbuf);
    }
    assert_eq!(allocs() - a0, 0, "sparse dist kernels allocated");
}
