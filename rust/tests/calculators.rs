//! Cross-validation of the acceptance-rate (Alg. 6–10) and branching
//! (Alg. 11–15) calculators against Monte-Carlo runs of the corresponding
//! solvers, over randomized (p, q) pairs — the paper's own validation
//! methodology, applied systematically.

use specdelay::dist::{Dist, NodeDist};
use specdelay::util::Pcg64;
use specdelay::verify::{ot_solver, OtlpSolver};

fn random_dist(v: usize, rng: &mut Pcg64, sharp: f32) -> Dist {
    let mut d: Vec<f32> = (0..v).map(|_| rng.next_f32().powf(sharp) + 1e-3).collect();
    let s: f32 = d.iter().sum();
    for x in d.iter_mut() {
        *x /= s;
    }
    Dist(d)
}

fn check_solver(name: &str, trials: usize) {
    let solver = ot_solver(name).unwrap();
    let mut rng = Pcg64::seeded(777);
    for trial in 0..trials {
        let v = 3 + rng.next_below(6);
        let p = random_dist(v, &mut rng, 2.0);
        let q = random_dist(v, &mut rng, 1.0);
        let k = 1 + rng.next_below(4);

        // acceptance rate vs MC
        let rate = solver.acceptance_rate(&p, &q, k);
        let (pn, qn) = (NodeDist::from(p.clone()), NodeDist::from(q.clone()));
        let n = 40_000;
        let mut hits = 0usize;
        for _ in 0..n {
            let xs: Vec<u32> = (0..k).map(|_| q.sample(&mut rng) as u32).collect();
            let y = solver.solve(&pn, &qn, &xs, &mut rng);
            if xs.contains(&y) {
                hits += 1;
            }
        }
        let mc = hits as f64 / n as f64;
        let tol = 5.0 * (rate * (1.0 - rate) / n as f64).sqrt() + 0.004;
        // Khisti's calculator is a documented canonical bound, not exact.
        if name == "Khisti" {
            assert!(
                mc <= rate + tol,
                "{name} trial {trial}: mc {mc} exceeds canonical bound {rate}"
            );
        } else {
            assert!(
                (mc - rate).abs() < tol,
                "{name} trial {trial} k={k}: mc {mc} vs exact {rate} (tol {tol})"
            );
        }

        // branching vs MC on a fixed draw
        let xs: Vec<u32> = (0..k).map(|_| q.sample(&mut rng) as u32).collect();
        let b = solver.branching(&pn, &qn, &xs);
        // the sparse representation computes the identical table
        let bs = solver.branching(&pn.sparsify(), &qn.sparsify(), &xs);
        for (i, (a, c)) in b.iter().zip(&bs).enumerate() {
            assert!(
                (a - c).abs() <= 1e-12,
                "{name} trial {trial} pos {i}: dense {a} vs sparse {c}"
            );
        }
        let n2 = 40_000;
        let mut counts = vec![0usize; v];
        for _ in 0..n2 {
            counts[solver.solve(&pn, &qn, &xs, &mut rng) as usize] += 1;
        }
        for (i, &x) in xs.iter().enumerate() {
            let mc = counts[x as usize] as f64 / n2 as f64;
            let tol = 5.0 * (b[i].max(0.01) * (1.0 - b[i].min(0.99)) / n2 as f64).sqrt() + 0.005;
            assert!(
                (mc - b[i]).abs() < tol,
                "{name} trial {trial} branching pos {i}: mc {mc} vs {} (tol {tol})",
                b[i]
            );
        }
    }
}

#[test]
fn nss_calculators() {
    check_solver("NSS", 8);
}

#[test]
fn naive_calculators() {
    check_solver("Naive", 8);
}

#[test]
fn spectr_calculators() {
    check_solver("SpecTr", 8);
}

#[test]
fn specinfer_calculators() {
    check_solver("SpecInfer", 6);
}

#[test]
fn khisti_calculators() {
    check_solver("Khisti", 5);
}

/// Acceptance-rate ordering sanity: all methods ≥ NSS-with-k... and
/// acceptance increases with k for every solver.
#[test]
fn acceptance_monotone_in_k() {
    let mut rng = Pcg64::seeded(55);
    for name in ["NSS", "Naive", "SpecTr", "SpecInfer", "Khisti"] {
        let solver = ot_solver(name).unwrap();
        for _ in 0..5 {
            let p = random_dist(6, &mut rng, 2.0);
            let q = random_dist(6, &mut rng, 1.0);
            let mut prev = 0.0;
            for k in 1..=4 {
                let r = solver.acceptance_rate(&p, &q, k);
                assert!(
                    r >= prev - 1e-9,
                    "{name}: acceptance must grow with k ({prev} -> {r})"
                );
                prev = r;
            }
        }
    }
}
