"""L2 model consistency: prefill == iterated decode == rollout == tree
verify on a tiny config. These are the invariants the rust coordinator
relies on across the AOT boundary."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M

CFG = M.ModelConfig(n_layers=2, d_model=64, n_heads=2, d_head=32, max_seq=128)


@pytest.fixture(scope="module")
def params():
    return M.init_params(CFG, 0)


@pytest.fixture(scope="module")
def setup(params):
    """Common prefix: 10 tokens decoded into a cache."""
    rng = np.random.default_rng(0)
    toks = rng.integers(0, 256, 10).astype(np.int32)
    L, H, S, Dh = CFG.n_layers, CFG.n_heads, CFG.max_seq, CFG.d_head
    kc = np.zeros((L, H, S, Dh), np.float32)
    vc = np.zeros_like(kc)
    decode = M.jit_decode(CFG)
    logits = None
    for t in range(len(toks)):
        logits, hid, kr, vr = decode(params, jnp.array(kc), jnp.array(vc), int(toks[t]), t)
        kc[:, :, t] = np.array(kr)
        vc[:, :, t] = np.array(vr)
    return toks, kc, vc, np.array(logits)


def test_prefill_matches_decode(params, setup):
    toks, kc, vc, last_logits = setup
    prefill = M.jit_prefill(CFG, 16)
    padded = np.concatenate([toks, np.full(6, 258, np.int32)])
    logits, hid, k_rows, v_rows = prefill(params, jnp.array(padded), len(toks))
    np.testing.assert_allclose(np.array(logits), last_logits, atol=2e-5)
    np.testing.assert_allclose(np.array(k_rows)[:, :, :len(toks)], kc[:, :, :len(toks)], atol=2e-5)


def test_rollout_k1_matches_decode_dist(params, setup):
    toks, kc, vc, last_logits = setup
    roll = M.jit_rollout(CFG, 1, 3)
    u = jnp.full((1, 3), 0.3)
    tk, ds, hs, krr, vrr = roll(params, jnp.array(kc), jnp.array(vc),
                                int(toks[-1]), len(toks) - 1, u, 1.0, 1.0)
    ref = np.array(jax.nn.softmax(jnp.array(last_logits)))
    np.testing.assert_allclose(np.array(ds[0, 0]), ref, atol=1e-5)


def test_rollout_branches_share_step0(params, setup):
    toks, kc, vc, _ = setup
    roll = M.jit_rollout(CFG, 3, 2)
    rng = np.random.default_rng(1)
    u = jnp.array(rng.random((3, 2)), dtype=jnp.float32)
    tk, ds, hs, krr, vrr = roll(params, jnp.array(kc), jnp.array(vc),
                                int(toks[-1]), len(toks) - 1, u, 0.8, 0.95)
    # all branches compute the identical step-0 distribution (same context)
    np.testing.assert_allclose(np.array(ds[0, 0]), np.array(ds[1, 0]), atol=1e-6)
    np.testing.assert_allclose(np.array(ds[0, 0]), np.array(ds[2, 0]), atol=1e-6)
    # rows at step 0 identical across branches
    np.testing.assert_allclose(np.array(krr[:, 0, 0]), np.array(krr[:, 1, 0]), atol=1e-6)


def test_tree_verify_single_path_matches_decode(params, setup):
    toks, kc, vc, last_logits = setup
    N = 8
    path = [int(toks[-1]), 5, 77, 200]
    tree_toks = np.full(N, 258, np.int32)
    tree_pos = np.full(N, CFG.max_seq - 1, np.int32)
    bias = np.full((N, N), -1e30, np.float32)
    np.fill_diagonal(bias, 0.0)
    for i, tok in enumerate(path):
        tree_toks[i] = tok
        tree_pos[i] = len(toks) - 1 + i
        for j in range(i + 1):
            bias[i, j] = 0.0
    tv = M.jit_tree_verify(CFG, N)
    lg, hid, kr, vr = tv(params, jnp.array(kc), jnp.array(vc), jnp.array(tree_toks),
                         jnp.array(tree_pos), jnp.array(bias), len(toks) - 1)
    np.testing.assert_allclose(np.array(lg[0]), last_logits, atol=2e-5)

    # decode the path and compare deeper nodes
    decode = M.jit_decode(CFG)
    kc2, vc2 = kc.copy(), vc.copy()
    for i, tok in enumerate(path):
        lgd, hdd, krd, vrd = decode(params, jnp.array(kc2), jnp.array(vc2), tok,
                                    len(toks) - 1 + i)
        kc2[:, :, len(toks) - 1 + i] = np.array(krd)
        vc2[:, :, len(toks) - 1 + i] = np.array(vrd)
        np.testing.assert_allclose(np.array(lg[i]), np.array(lgd), atol=5e-5)


def test_sibling_isolation_in_tree(params, setup):
    """A node must not attend to a non-ancestor sibling."""
    toks, kc, vc, _ = setup
    N = 4
    root = int(toks[-1])
    # tree: root -> a, root -> b (siblings)
    tree_toks = np.array([root, 10, 20, 258], np.int32)
    tree_pos = np.array([len(toks) - 1, len(toks), len(toks), CFG.max_seq - 1], np.int32)
    bias = np.full((N, N), -1e30, np.float32)
    np.fill_diagonal(bias, 0.0)
    bias[1, 0] = 0.0
    bias[2, 0] = 0.0
    tv = M.jit_tree_verify(CFG, N)
    lg1, *_ = tv(params, jnp.array(kc), jnp.array(vc), jnp.array(tree_toks),
                 jnp.array(tree_pos), jnp.array(bias), len(toks) - 1)
    # change sibling b's token: node a's logits must be unchanged
    tree_toks2 = tree_toks.copy()
    tree_toks2[2] = 99
    lg2, *_ = tv(params, jnp.array(kc), jnp.array(vc), jnp.array(tree_toks2),
                 jnp.array(tree_pos), jnp.array(bias), len(toks) - 1)
    np.testing.assert_allclose(np.array(lg1[1]), np.array(lg2[1]), atol=1e-6)
