"""Pallas tree-attention kernel vs the pure-jnp oracle — the core L1
correctness signal. Hypothesis sweeps shapes, cache lengths and masks."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.ref import tree_attention_ref
from compile.kernels.tree_attention import tree_attention, vmem_footprint_bytes


def random_case(rng, h, n, s, dh, cache_len, block_s):
    q = rng.normal(size=(h, n, dh)).astype(np.float32)
    kc = rng.normal(size=(h, s, dh)).astype(np.float32)
    vc = rng.normal(size=(h, s, dh)).astype(np.float32)
    kt = rng.normal(size=(h, n, dh)).astype(np.float32)
    vt = rng.normal(size=(h, n, dh)).astype(np.float32)
    # random ancestor-ish mask with self-visibility
    bias = np.where(rng.random((n, n)) < 0.5, 0.0, -1e30).astype(np.float32)
    np.fill_diagonal(bias, 0.0)
    return q, kc, vc, kt, vt, bias


@settings(max_examples=12, deadline=None)
@given(
    h=st.integers(1, 3),
    n=st.sampled_from([1, 4, 8]),
    s_tiles=st.integers(1, 3),
    dh=st.sampled_from([8, 32]),
    seed=st.integers(0, 2**16),
    frac=st.floats(0.0, 1.0),
)
def test_kernel_matches_ref(h, n, s_tiles, dh, seed, frac):
    block_s = 64
    s = s_tiles * block_s
    cache_len = int(frac * (s - 1))
    rng = np.random.default_rng(seed)
    q, kc, vc, kt, vt, bias = random_case(rng, h, n, s, dh, cache_len, block_s)
    out = tree_attention(
        jnp.array(q), jnp.array(kc), jnp.array(vc),
        jnp.array(kt), jnp.array(vt), jnp.array(bias), cache_len,
        block_s=block_s)
    ref = tree_attention_ref(
        jnp.array(q), jnp.array(kc), jnp.array(vc),
        jnp.array(kt), jnp.array(vt), jnp.array(bias), cache_len)
    np.testing.assert_allclose(np.array(out), np.array(ref), atol=2e-5, rtol=2e-4)


def test_zero_cache_len_uses_only_tree():
    rng = np.random.default_rng(0)
    q, kc, vc, kt, vt, bias = random_case(rng, 2, 4, 128, 16, 0, 128)
    out = tree_attention(jnp.array(q), jnp.array(kc), jnp.array(vc),
                         jnp.array(kt), jnp.array(vt), jnp.array(bias), 0)
    # perturbing the cache must not change the output when cache_len == 0
    out2 = tree_attention(jnp.array(q), jnp.array(kc + 100.0), jnp.array(vc - 5.0),
                          jnp.array(kt), jnp.array(vt), jnp.array(bias), 0)
    np.testing.assert_allclose(np.array(out), np.array(out2), atol=1e-6)


def test_rejects_unaligned_s():
    rng = np.random.default_rng(1)
    q, kc, vc, kt, vt, bias = random_case(rng, 1, 2, 100, 8, 10, 128)
    with pytest.raises(ValueError):
        tree_attention(jnp.array(q), jnp.array(kc), jnp.array(vc),
                       jnp.array(kt), jnp.array(vt), jnp.array(bias), 10)


def test_vmem_footprint_reasonable():
    # DESIGN.md §Perf: resident tree block + double-buffered KV tiles must
    # fit in 16 MiB VMEM with room to spare at production shapes.
    assert vmem_footprint_bytes(n=48, s=384, dh=64) < 2 * 2**20
