"""Synthetic corpus determinism and structure."""

from compile import corpus


def test_deterministic():
    assert corpus.build_corpus(seed=3, docs_per_domain=20) == corpus.build_corpus(
        seed=3, docs_per_domain=20)
    assert corpus.build_corpus(seed=3, docs_per_domain=20) != corpus.build_corpus(
        seed=4, docs_per_domain=20)


def test_all_domains_present():
    text = corpus.build_corpus(seed=0, docs_per_domain=30).decode("utf-8")
    assert "story:" in text
    assert "def " in text
    assert "translate en->" in text
    assert "Q: " in text and "A: " in text
    assert "step1:" in text


def test_prompts_are_prefixes():
    prompts = corpus.build_prompts(per_domain=10)
    assert set(prompts) == set(corpus.DOMAINS)
    for domain, items in prompts.items():
        assert len(items) == 10
        for p in items:
            assert 0 < len(p) < 200
    # coding prompts end right after the signature
    assert all(p.rstrip().endswith("):") for p in prompts["coding"])
    # translation prompts stop at the arrow
    assert all("=>" in p for p in prompts["translation"])


def test_ascii_only():
    # byte-level models: keep the corpus single-byte to avoid partial UTF-8
    data = corpus.build_corpus(seed=0, docs_per_domain=50)
    assert all(b < 128 for b in data)
