"""transform_dist / sample_from semantics — these must mirror the rust
dist::Dist implementation exactly (same nucleus rule, same tie-breaking)."""

import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile.model import sample_from, transform_dist


def test_topp_truncation_rule():
    # probs for logits [3,2,1,0] ~ [.643,.236,.087,.032]; top_p=0.8 keeps
    # tokens while the exclusive cumulative mass is < 0.8 -> first two.
    d = np.array(transform_dist(jnp.array([3.0, 2.0, 1.0, 0.0]), 1.0, 0.8))
    assert d[2] == 0.0 and d[3] == 0.0
    assert abs(d.sum() - 1.0) < 1e-6


def test_topp_one_keeps_all():
    d = np.array(transform_dist(jnp.array([0.0, 0.0, 0.0]), 1.0, 1.0))
    np.testing.assert_allclose(d, np.ones(3) / 3, atol=1e-6)


def test_temperature_sharpens():
    cold = np.array(transform_dist(jnp.array([1.0, 2.0]), 0.2, 1.0))
    hot = np.array(transform_dist(jnp.array([1.0, 2.0]), 2.0, 1.0))
    assert cold[1] > hot[1]


@settings(max_examples=30, deadline=None)
@given(seed=st.integers(0, 2**16), temp=st.floats(0.1, 2.0), topp=st.floats(0.05, 1.0))
def test_transform_always_valid(seed, temp, topp):
    rng = np.random.default_rng(seed)
    logits = jnp.array(rng.normal(size=16).astype(np.float32) * 4)
    d = np.array(transform_dist(logits, temp, topp))
    assert abs(d.sum() - 1.0) < 1e-4
    assert (d >= 0).all()
    assert d.max() > 0


def test_inverse_cdf_sampling():
    probs = jnp.array([0.2, 0.5, 0.3])
    assert int(sample_from(probs, jnp.array(0.1))) == 0
    assert int(sample_from(probs, jnp.array(0.3))) == 1
    assert int(sample_from(probs, jnp.array(0.95))) == 2
    # u ~ 1.0 clamps to the last token
    assert int(sample_from(probs, jnp.array(0.999999))) == 2
