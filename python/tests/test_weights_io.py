"""Weights container round-trip (the rust reader mirrors this format)."""

import numpy as np
import pytest

from compile.weights_io import read_tensors, write_tensors


def test_roundtrip(tmp_path):
    tensors = [
        ("emb", np.arange(12, dtype=np.float32).reshape(3, 4)),
        ("bias", np.array([1.5, -2.0], dtype=np.float32)),
        ("scalar", np.array(7.0, dtype=np.float32)),
    ]
    path = str(tmp_path / "w.bin")
    write_tensors(path, tensors)
    back = read_tensors(path)
    assert [n for n, _ in back] == ["emb", "bias", "scalar"]
    for (n1, a1), (n2, a2) in zip(tensors, back):
        np.testing.assert_array_equal(np.asarray(a1, np.float32), a2)


def test_order_preserved(tmp_path):
    tensors = [(f"t{i}", np.full(2, i, np.float32)) for i in range(20)]
    path = str(tmp_path / "many.bin")
    write_tensors(path, tensors)
    back = read_tensors(path)
    assert [n for n, _ in back] == [f"t{i}" for i in range(20)]


def test_bad_magic_rejected(tmp_path):
    path = str(tmp_path / "bad.bin")
    with open(path, "wb") as f:
        f.write(b"\x00" * 16)
    with pytest.raises(AssertionError):
        read_tensors(path)
