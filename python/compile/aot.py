"""AOT export: train (or load cached) model pairs and lower every entry point
to HLO *text* under artifacts/.

HLO text — not ``lowered.compiler_ir("hlo")`` protos and not ``.serialize()``
— is the interchange format: jax >= 0.5 emits HloModuleProto with 64-bit
instruction ids which xla_extension 0.5.1 (the version behind the rust `xla`
crate) rejects; the text parser reassigns ids and round-trips cleanly
(see /opt/xla-example/README.md).

Layout:
    artifacts/<family>/meta.json
    artifacts/<family>/{target,draft}.bin          # weights, HLO arg order
    artifacts/<family>/hlo/<entry>.hlo.txt
    artifacts/prompts/<domain>.json                # held-out bench prompts

Run:  cd python && python -m compile.aot --out ../artifacts
"""

from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import corpus as corpus_mod
from . import train as train_mod
from .model import (ModelConfig, make_decode, make_prefill, make_rollout,
                    make_tree_verify, param_names)
from .weights_io import read_tensors, write_tensors

S_PRE = 192                      # prefill window (prompts are shorter)
TREE_SIZES = (8, 16, 32, 48)     # online tree-pass buckets
TREE_BIG = 320                   # offline superset tree (trace collection)
TRUNK_LENS = tuple(range(1, 9))  # trunk rollout variants (K=1)
BRANCH_KS = (2, 3, 4)
BRANCH_LENS = (2, 4, 6, 8)


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True)
    return comp.as_hlo_text()


def _sds(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(shape, dtype)


def _kv_sds(cfg: ModelConfig):
    shape = (cfg.n_layers, cfg.n_heads, cfg.max_seq, cfg.d_head)
    return _sds(shape), _sds(shape)


def _params_sds(cfg: ModelConfig, params):
    return [jax.ShapeDtypeStruct(p.shape, p.dtype) for p in params]


def lower_entries(cfg: ModelConfig, params, role: str, hlo_dir: str) -> dict:
    """Lower every entry point for one model; returns entry metadata."""
    os.makedirs(hlo_dir, exist_ok=True)
    psds = _params_sds(cfg, params)
    k_sds, v_sds = _kv_sds(cfg)
    i32 = jnp.int32
    entries = {}

    def emit(name, fn, *args):
        t0 = time.time()
        lowered = jax.jit(fn).lower(*args)
        text = to_hlo_text(lowered)
        path = os.path.join(hlo_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        entries[name] = {"file": f"hlo/{name}.hlo.txt"}
        print(f"  [aot] {name}: {len(text) // 1024} KiB ({time.time() - t0:.1f}s)",
              flush=True)

    emit(f"{role}_prefill", make_prefill(cfg, S_PRE),
         psds, _sds((S_PRE,), i32), _sds((), i32))
    emit(f"{role}_decode", make_decode(cfg),
         psds, k_sds, v_sds, _sds((), i32), _sds((), i32))

    if role == "draft":
        for l in TRUNK_LENS:
            emit(f"draft_rollout_k1_l{l}", make_rollout(cfg, 1, l),
                 psds, k_sds, v_sds, _sds((), i32), _sds((), i32),
                 _sds((1, l)), _sds(()), _sds(()))
        for k in BRANCH_KS:
            for l in BRANCH_LENS:
                emit(f"draft_rollout_k{k}_l{l}", make_rollout(cfg, k, l),
                     psds, k_sds, v_sds, _sds((), i32), _sds((), i32),
                     _sds((k, l)), _sds(()), _sds(()))
    else:
        for n in TREE_SIZES + (TREE_BIG,):
            emit(f"target_tree_n{n}", make_tree_verify(cfg, n),
                 psds, k_sds, v_sds, _sds((n,), i32), _sds((n,), i32),
                 _sds((n, n)), _sds((), i32))
    return entries


def cfg_meta(cfg: ModelConfig, params) -> dict:
    return {
        "n_layers": cfg.n_layers, "d_model": cfg.d_model,
        "n_heads": cfg.n_heads, "d_head": cfg.d_head,
        "vocab": cfg.vocab, "max_seq": cfg.max_seq,
        "n_params": int(sum(int(np.prod(p.shape)) for p in params)),
    }


def build_family(name: str, out_dir: str, steps: int | None) -> None:
    fam_dir = os.path.join(out_dir, name)
    os.makedirs(fam_dir, exist_ok=True)
    spec = train_mod.FAMILIES[name]
    t_path = os.path.join(fam_dir, "target.bin")
    d_path = os.path.join(fam_dir, "draft.bin")

    if os.path.exists(t_path) and os.path.exists(d_path):
        print(f"[aot] {name}: cached weights found, skipping training")
        target = [jnp.asarray(a) for _, a in read_tensors(t_path)]
        draft = [jnp.asarray(a) for _, a in read_tensors(d_path)]
        t_loss = d_loss = -1.0
    else:
        target, draft, t_loss, d_loss = train_mod.train_family(name, steps=steps)
        write_tensors(t_path, list(zip(param_names(spec["target"]),
                                       [np.asarray(p) for p in target])))
        write_tensors(d_path, list(zip(param_names(spec["draft"]),
                                       [np.asarray(p) for p in draft])))

    hlo_dir = os.path.join(fam_dir, "hlo")
    entries = {}
    entries.update(lower_entries(spec["target"], target, "target", hlo_dir))
    entries.update(lower_entries(spec["draft"], draft, "draft", hlo_dir))

    meta = {
        "family": name,
        "target": cfg_meta(spec["target"], target),
        "draft": cfg_meta(spec["draft"], draft),
        "s_pre": S_PRE,
        "tree_sizes": list(TREE_SIZES), "tree_big": TREE_BIG,
        "trunk_lens": list(TRUNK_LENS),
        "branch_ks": list(BRANCH_KS), "branch_lens": list(BRANCH_LENS),
        "train_loss": {"target": t_loss, "draft": d_loss},
        "entries": entries,
    }
    with open(os.path.join(fam_dir, "meta.json"), "w") as f:
        json.dump(meta, f, indent=1)
    print(f"[aot] {name}: wrote {len(entries)} entries")


def write_prompts(out_dir: str) -> None:
    pdir = os.path.join(out_dir, "prompts")
    os.makedirs(pdir, exist_ok=True)
    prompts = corpus_mod.build_prompts()
    for domain, items in prompts.items():
        with open(os.path.join(pdir, f"{domain}.json"), "w") as f:
            json.dump(items, f, indent=0)
    print(f"[aot] wrote prompts for {len(prompts)} domains")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--families", default=",".join(train_mod.FAMILIES))
    ap.add_argument("--steps", type=int, default=None,
                    help="override training steps (default env SPECDELAY_TRAIN_STEPS or 300)")
    args = ap.parse_args()

    os.makedirs(args.out, exist_ok=True)
    write_prompts(args.out)
    for fam in args.families.split(","):
        build_family(fam.strip(), args.out, args.steps)
    with open(os.path.join(args.out, "families.json"), "w") as f:
        json.dump([f.strip() for f in args.families.split(",")], f)
    print("[aot] done")


if __name__ == "__main__":
    main()
