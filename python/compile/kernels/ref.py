"""Pure-jnp oracle for the tree-attention kernel.

Materializes the full (N, S+N) score matrix; used only as the correctness
reference in pytest and never lowered into artifacts.
"""

from __future__ import annotations

import jax.numpy as jnp


def tree_attention_ref(q, k_cache, v_cache, k_tree, v_tree, tree_bias, cache_len):
    """Reference tree attention.

    Args:
      q:         [H, N, Dh]  queries for the N draft-tree nodes (RoPE applied).
      k_cache:   [H, S, Dh]  committed-prefix keys.
      v_cache:   [H, S, Dh]  committed-prefix values.
      k_tree:    [H, N, Dh]  keys of the tree nodes themselves.
      v_tree:    [H, N, Dh]  values of the tree nodes.
      tree_bias: [N, N]      additive mask over tree->tree attention;
                             0 where node j is an ancestor-or-self of node i,
                             -inf (large negative) otherwise.
      cache_len: int32       number of valid prefix rows (< S).

    Returns:
      [H, N, Dh] attention outputs.
    """
    h, n, dh = q.shape
    s = k_cache.shape[1]
    scale = 1.0 / jnp.sqrt(jnp.asarray(dh, jnp.float32))

    scores_cache = jnp.einsum("hnd,hsd->hns", q, k_cache) * scale  # [H,N,S]
    pos = jnp.arange(s)[None, None, :]
    scores_cache = jnp.where(pos < cache_len, scores_cache, -1e30)

    scores_tree = jnp.einsum("hnd,hmd->hnm", q, k_tree) * scale  # [H,N,N]
    scores_tree = scores_tree + tree_bias[None, :, :]

    scores = jnp.concatenate([scores_cache, scores_tree], axis=-1)  # [H,N,S+N]
    probs = jnp.exp(scores - scores.max(axis=-1, keepdims=True))
    probs = probs / probs.sum(axis=-1, keepdims=True)
    vals = jnp.concatenate([v_cache, v_tree], axis=1)  # [H, S+N, Dh]
    return jnp.einsum("hnk,hkd->hnd", probs, vals)
