"""Pallas tree-attention kernel (layer 1).

The paper's target pass is a batched forward over the draft tree with an
ancestor-only attention mask — on GPUs this is done inside fused attention
kernels with the tree mask applied per threadblock. Here the insight is
re-thought for the TPU/Pallas execution model (DESIGN.md §Hardware-Adaptation):

* the committed KV prefix is streamed HBM→VMEM in `BLOCK_S` tiles through a
  flash-attention-style running (max, denominator, accumulator) carried by a
  `fori_loop` — the VMEM analogue of the paper's threadblock KV tiling;
* the (small) tree block — queries, tree keys/values, and the NxN ancestor
  bias — stays VMEM-resident for the whole kernel;
* scores are `(N, Dh) x (Dh, BLOCK_S)` matmuls so the MXU systolic array is
  fed with tree nodes as rows; the ancestor mask is an additive bias, never
  control flow.

Grid is one program per attention head. `interpret=True` everywhere: the CPU
PJRT plugin cannot execute Mosaic custom-calls, so the kernel lowers to plain
HLO; real-TPU perf is estimated from the VMEM footprint + MXU utilization of
these block shapes in DESIGN.md / EXPERIMENTS.md §Perf.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# KV prefix tile. 128 rows of Dh=64 f32 keys+values = 64 KiB per tile — two
# tiles (double buffering) plus the resident tree block fit comfortably in
# 16 MiB VMEM; 128 is also the MXU lane width.
BLOCK_S = 128

NEG_INF = -1e30


def _tree_attn_kernel(len_ref, q_ref, kc_ref, vc_ref, kt_ref, vt_ref, bias_ref,
                      o_ref, *, block_s: int):
    """One head: flash attention over [prefix tiles ... tree block]."""
    q = q_ref[0]            # [N, Dh]   VMEM-resident
    k_tree = kt_ref[0]      # [N, Dh]
    v_tree = vt_ref[0]      # [N, Dh]
    bias = bias_ref[...]    # [N, N]
    cache_len = len_ref[0]

    n, dh = q.shape
    s_total = kc_ref.shape[1]
    scale = 1.0 / jnp.sqrt(jnp.asarray(dh, jnp.float32))
    qs = q * scale

    num_tiles = s_total // block_s

    def tile_step(t, carry):
        m, l, acc = carry
        k = jax.lax.dynamic_slice_in_dim(kc_ref[0], t * block_s, block_s, axis=0)
        v = jax.lax.dynamic_slice_in_dim(vc_ref[0], t * block_s, block_s, axis=0)
        # (N, Dh) x (Dh, BLOCK_S) — MXU-shaped.
        scores = jnp.dot(qs, k.T)  # [N, block_s]
        pos = t * block_s + jax.lax.iota(jnp.int32, block_s)[None, :]
        scores = jnp.where(pos < cache_len, scores, NEG_INF)
        m_new = jnp.maximum(m, scores.max(axis=-1))
        alpha = jnp.exp(m - m_new)
        p = jnp.exp(scores - m_new[:, None])
        l_new = l * alpha + p.sum(axis=-1)
        acc_new = acc * alpha[:, None] + jnp.dot(p, v)
        return m_new, l_new, acc_new

    m0 = jnp.full((n,), NEG_INF, jnp.float32)
    l0 = jnp.zeros((n,), jnp.float32)
    acc0 = jnp.zeros((n, dh), jnp.float32)
    m, l, acc = jax.lax.fori_loop(0, num_tiles, tile_step, (m0, l0, acc0))

    # Final stage: the VMEM-resident tree block with the ancestor bias.
    scores = jnp.dot(qs, k_tree.T) + bias  # [N, N]
    m_new = jnp.maximum(m, scores.max(axis=-1))
    alpha = jnp.exp(m - m_new)
    p = jnp.exp(scores - m_new[:, None])
    l_fin = l * alpha + p.sum(axis=-1)
    acc_fin = acc * alpha[:, None] + jnp.dot(p, v_tree)

    o_ref[0] = acc_fin / l_fin[:, None]


@functools.partial(jax.jit, static_argnames=("block_s",))
def tree_attention(q, k_cache, v_cache, k_tree, v_tree, tree_bias, cache_len,
                   *, block_s: int = BLOCK_S):
    """Tree attention via the Pallas kernel.

    Args:
      q:         [H, N, Dh] node queries (RoPE already applied).
      k_cache:   [H, S, Dh] committed prefix keys; S must be a multiple of
                 `block_s`.
      v_cache:   [H, S, Dh].
      k_tree:    [H, N, Dh] tree-node keys.
      v_tree:    [H, N, Dh].
      tree_bias: [N, N] additive ancestor mask (0 allowed / -1e30 blocked).
      cache_len: int32 scalar, number of valid prefix rows.

    Returns:
      [H, N, Dh] attention outputs.
    """
    h, n, dh = q.shape
    s = k_cache.shape[1]
    if s % block_s != 0:
        raise ValueError(f"S={s} must be a multiple of block_s={block_s}")
    cache_len_arr = jnp.asarray(cache_len, jnp.int32).reshape(1)

    kernel = functools.partial(_tree_attn_kernel, block_s=block_s)
    return pl.pallas_call(
        kernel,
        grid=(h,),
        in_specs=[
            pl.BlockSpec((1,), lambda i: (0,)),             # cache_len
            pl.BlockSpec((1, n, dh), lambda i: (i, 0, 0)),  # q
            pl.BlockSpec((1, s, dh), lambda i: (i, 0, 0)),  # k_cache
            pl.BlockSpec((1, s, dh), lambda i: (i, 0, 0)),  # v_cache
            pl.BlockSpec((1, n, dh), lambda i: (i, 0, 0)),  # k_tree
            pl.BlockSpec((1, n, dh), lambda i: (i, 0, 0)),  # v_tree
            pl.BlockSpec((n, n), lambda i: (0, 0)),         # bias
        ],
        out_specs=pl.BlockSpec((1, n, dh), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((h, n, dh), jnp.float32),
        interpret=True,
    )(cache_len_arr, q, k_cache, v_cache, k_tree, v_tree, tree_bias)


def vmem_footprint_bytes(n: int, s: int, dh: int, block_s: int = BLOCK_S) -> int:
    """Estimated per-program VMEM residency for DESIGN.md §Perf.

    Resident: q, k_tree, v_tree, bias, accumulators + two KV prefix tiles
    (double buffered).
    """
    f32 = 4
    resident = (3 * n * dh + n * n + n * (dh + 2)) * f32
    tiles = 2 * 2 * block_s * dh * f32
    return resident + tiles
