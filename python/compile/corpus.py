"""Deterministic synthetic multi-domain corpus.

The paper evaluates on five generative settings (math-easy = MATH500,
math-hard = OlympiadBench, coding = LiveCodeBench, creative writing =
LitBench, translation = Opus). We cannot ship those datasets, so we build a
synthetic analogue per domain from small grammars. What the experiments
consume is only the *draft/target distribution agreement per domain*, and the
grammars are designed so that agreement varies the same way it does in the
paper: code and math are locally deterministic (high agreement, long accepted
blocks), creative writing has high branching entropy, translation sits in
between with long copied spans.

Everything is seeded and reproducible; the same module also emits held-out
prompt sets used by the rust bench harness (written by aot.py into
artifacts/prompts/).
"""

from __future__ import annotations

import random

DOMAINS = ("writing", "coding", "translation", "math_easy", "math_hard")

# ---------------------------------------------------------------------------
# Shared vocabulary fragments
# ---------------------------------------------------------------------------

_NOUNS = [
    "river", "lantern", "harbor", "meadow", "engine", "letter", "garden",
    "violin", "winter", "mirror", "forest", "signal", "anchor", "castle",
    "shadow", "market", "dancer", "sailor", "mountain", "archive",
]
_ADJS = [
    "quiet", "golden", "distant", "broken", "gentle", "hollow", "silver",
    "ancient", "restless", "pale", "luminous", "weathered", "crimson",
]
_VERBS = [
    "drifted", "glowed", "trembled", "vanished", "unfolded", "lingered",
    "whispered", "wandered", "settled", "burned", "echoed", "dissolved",
]
_ADVS = ["slowly", "quietly", "suddenly", "gracefully", "finally", "softly"]

_EN_FR = [
    ("the house", "la maison"), ("the sea", "la mer"), ("a small bird", "un petit oiseau"),
    ("the old man", "le vieil homme"), ("the city", "la ville"), ("my friend", "mon ami"),
    ("the night", "la nuit"), ("a long road", "une longue route"), ("the sun", "le soleil"),
    ("the garden", "le jardin"), ("a quiet voice", "une voix calme"), ("the winter", "l'hiver"),
]
_EN_ES = [
    ("the house", "la casa"), ("the sea", "el mar"), ("a small bird", "un pajaro pequeno"),
    ("the old man", "el viejo"), ("the city", "la ciudad"), ("my friend", "mi amigo"),
    ("the night", "la noche"), ("a long road", "un camino largo"), ("the sun", "el sol"),
    ("the garden", "el jardin"), ("a quiet voice", "una voz tranquila"), ("the winter", "el invierno"),
]

_FUNCS = ["scan", "fold", "merge", "split", "rank", "pack", "trim", "join"]
_VARS = ["xs", "ys", "acc", "out", "buf", "val", "idx", "tmp"]


# ---------------------------------------------------------------------------
# Per-domain document generators
# ---------------------------------------------------------------------------

def gen_writing(rng: random.Random) -> str:
    lines = []
    for _ in range(rng.randint(2, 4)):
        n1, n2 = rng.choice(_NOUNS), rng.choice(_NOUNS)
        a1, a2 = rng.choice(_ADJS), rng.choice(_ADJS)
        v1, v2 = rng.choice(_VERBS), rng.choice(_VERBS)
        adv = rng.choice(_ADVS)
        form = rng.randrange(4)
        if form == 0:
            lines.append(f"The {a1} {n1} {v1} {adv} beyond the {a2} {n2}.")
        elif form == 1:
            lines.append(f"Under a {a1} sky, the {n1} {v1} while the {n2} {v2}.")
        elif form == 2:
            lines.append(f"No one saw how the {n1} {v1}; only the {a2} {n2} {v2} {adv}.")
        else:
            lines.append(f"It was the {n1} that {v1} first, {adv}, like a {a1} {n2}.")
    return "story: " + " ".join(lines) + "\n"


def gen_coding(rng: random.Random) -> str:
    f = rng.choice(_FUNCS)
    a, b = rng.sample(_VARS, 2)
    k = rng.randint(1, 9)
    body = rng.randrange(3)
    out = [f"def {f}({a}, {b}):"]
    if body == 0:
        out += [f"    {b} = 0", f"    for v in {a}:", f"        {b} = {b} + v * {k}",
                f"    return {b}"]
    elif body == 1:
        out += [f"    if len({a}) == 0:", "        return []",
                f"    return [v + {k} for v in {a} if v > {b}]"]
    else:
        out += [f"    while {b} > 0:", f"        {a}.append({b} % {k + 1})",
                f"        {b} = {b} // {k + 1}", f"    return {a}"]
    return "code:\n" + "\n".join(out) + "\n"


def gen_translation(rng: random.Random) -> str:
    lex = _EN_FR if rng.random() < 0.5 else _EN_ES
    tag = "fr" if lex is _EN_FR else "es"
    pairs = rng.sample(lex, rng.randint(2, 3))
    en = " and ".join(p[0] for p in pairs)
    tr = " et ".join(p[1] for p in pairs) if tag == "fr" else " y ".join(p[1] for p in pairs)
    return f"translate en->{tag}: {en} => {tr}\n"


def gen_math_easy(rng: random.Random) -> str:
    a, b = rng.randint(2, 40), rng.randint(2, 40)
    op = rng.choice(["+", "-", "*"])
    val = {"+": a + b, "-": a - b, "*": a * b}[op]
    return f"Q: {a} {op} {b} = ? A: {val}\n"


def gen_math_hard(rng: random.Random) -> str:
    a, b, c = rng.randint(2, 20), rng.randint(2, 20), rng.randint(2, 12)
    form = rng.randrange(3)
    if form == 0:
        expr, val = f"({a} + {b}) * {c}", (a + b) * c
    elif form == 1:
        expr, val = f"{a} * {b} - {c} * {a}", a * b - c * a
    else:
        expr, val = f"({a} - {b}) * ({a} + {c})", (a - b) * (a + c)
    steps = f"step1: inner terms; step2: multiply; answer: {val}"
    return f"Q: {expr} = ? {steps}\n"


_GENERATORS = {
    "writing": gen_writing,
    "coding": gen_coding,
    "translation": gen_translation,
    "math_easy": gen_math_easy,
    "math_hard": gen_math_hard,
}


# ---------------------------------------------------------------------------
# Corpus / prompt assembly
# ---------------------------------------------------------------------------

def build_corpus(seed: int = 0, docs_per_domain: int = 2000) -> bytes:
    """Concatenated training corpus over all domains (UTF-8 bytes)."""
    rng = random.Random(seed)
    docs = []
    for domain in DOMAINS:
        gen = _GENERATORS[domain]
        for _ in range(docs_per_domain):
            docs.append(gen(rng))
    rng.shuffle(docs)
    return "".join(docs).encode("utf-8")


def build_prompts(seed: int = 1234, per_domain: int = 64) -> dict[str, list[str]]:
    """Held-out prompt prefixes per domain for the bench harness.

    A prompt is the *prefix* of a fresh document (cut before its natural
    completion) so the model continues in-domain.
    """
    rng = random.Random(seed)
    prompts: dict[str, list[str]] = {}
    for domain in DOMAINS:
        gen = _GENERATORS[domain]
        out = []
        for _ in range(per_domain):
            doc = gen(rng)
            if domain == "writing":
                cut = doc.index(":") + 2 + rng.randint(8, 20)
            elif domain == "coding":
                cut = doc.index("):") + 3
            elif domain == "translation":
                cut = doc.index("=>") + 3
            else:  # math domains: cut right after "A:" / "?" marker
                marker = "A:" if "A:" in doc else "?"
                cut = doc.index(marker) + len(marker)
            out.append(doc[:cut])
        prompts[domain] = out
    return prompts


if __name__ == "__main__":
    corpus = build_corpus(docs_per_domain=5)
    print(corpus.decode("utf-8"))
