"""Layer 2: functional byte-level GPT in JAX.

Five AOT entry points per model (lowered to HLO text by aot.py, executed from
the rust coordinator via PJRT):

  * ``prefill``      — prompt -> logits/hidden at the last token + full KV rows
  * ``decode``       — one-token autoregressive step (baseline + microbench)
  * ``rollout``      — fused draft rollout: K i.i.d. branches of length L in a
                       single call (K=1 is the delayed-expansion trunk). This
                       is what makes drafting cheap on the request path: one
                       PJRT dispatch per trunk / branch stage instead of one
                       per token. Sampling (temperature + nucleus) happens
                       inside, driven by caller-supplied uniforms, so rust
                       retains full control of randomness.
  * ``tree_verify``  — the paper's hot spot: batched target pass over the
                       draft tree with the ancestor mask, via the Pallas
                       tree-attention kernel (kernels/tree_attention.py).

KV caches live host-side in rust and are passed in/out as plain arrays; every
function is pure. Positions use RoPE so there is no trained positional table
to run off the end of.
"""

from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np

from .kernels.tree_attention import tree_attention

VOCAB = 259  # 256 bytes + BOS(256) + EOS(257) + PAD(258)
BOS, EOS, PAD = 256, 257, 258


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    n_layers: int
    d_model: int
    n_heads: int
    d_head: int = 64
    vocab: int = VOCAB
    max_seq: int = 384      # multiple of the kernel BLOCK_S
    mlp_ratio: int = 4

    @property
    def d_attn(self) -> int:
        return self.n_heads * self.d_head

    @property
    def d_mlp(self) -> int:
        return self.mlp_ratio * self.d_model


# Parameter layout (flat list — the exact HLO argument / weights-file order):
#   tok_emb [V, d]
#   per layer: ln1_g, ln1_b, wq [d, H*Dh], wk, wv, wo [H*Dh, d],
#              ln2_g, ln2_b, w1 [d, m], b1 [m], w2 [m, d], b2 [d]
#   lnf_g [d], lnf_b [d]
PER_LAYER = 12


def param_names(cfg: ModelConfig) -> list[str]:
    names = ["tok_emb"]
    for i in range(cfg.n_layers):
        names += [f"l{i}.{n}" for n in (
            "ln1_g", "ln1_b", "wq", "wk", "wv", "wo",
            "ln2_g", "ln2_b", "w1", "b1", "w2", "b2")]
    names += ["lnf_g", "lnf_b"]
    return names


def init_params(cfg: ModelConfig, seed: int) -> list[jnp.ndarray]:
    rng = np.random.default_rng(seed)
    d, da, m = cfg.d_model, cfg.d_attn, cfg.d_mlp

    def norm(*shape, scale=0.02):
        return jnp.asarray(rng.normal(0.0, scale, shape), jnp.float32)

    out_scale = 0.02 / np.sqrt(2 * cfg.n_layers)
    params: list[jnp.ndarray] = [norm(cfg.vocab, d)]
    for _ in range(cfg.n_layers):
        params += [
            jnp.ones(d), jnp.zeros(d),
            norm(d, da), norm(d, da), norm(d, da), norm(da, d, scale=out_scale),
            jnp.ones(d), jnp.zeros(d),
            norm(d, m), jnp.zeros(m), norm(m, d, scale=out_scale), jnp.zeros(d),
        ]
    params += [jnp.ones(d), jnp.zeros(d)]
    return params


def _layer_params(params, i):
    return params[1 + i * PER_LAYER: 1 + (i + 1) * PER_LAYER]


def _ln(x, g, b, eps=1e-5):
    mu = x.mean(-1, keepdims=True)
    var = ((x - mu) ** 2).mean(-1, keepdims=True)
    return (x - mu) / jnp.sqrt(var + eps) * g + b


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def _rope_freqs(d_head: int):
    return 10000.0 ** (-jnp.arange(0, d_head, 2, dtype=jnp.float32) / d_head)


def apply_rope(x, positions):
    """x: [..., T, H, Dh]; positions: [..., T] (int32)."""
    dh = x.shape[-1]
    theta = positions[..., :, None, None].astype(jnp.float32) * _rope_freqs(dh)
    cos, sin = jnp.cos(theta), jnp.sin(theta)
    x1, x2 = x[..., 0::2], x[..., 1::2]
    out = jnp.stack([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.reshape(x.shape)


# ---------------------------------------------------------------------------
# Training forward (python-only; never exported)
# ---------------------------------------------------------------------------

def train_forward(cfg: ModelConfig, params, tokens):
    """tokens: [B, T] int32 -> logits [B, T, V]. Plain causal attention."""
    b, t = tokens.shape
    h, dh = cfg.n_heads, cfg.d_head
    x = params[0][tokens]  # [B, T, d]
    pos = jnp.arange(t, dtype=jnp.int32)
    causal = jnp.tril(jnp.ones((t, t), bool))

    for i in range(cfg.n_layers):
        (ln1_g, ln1_b, wq, wk, wv, wo, ln2_g, ln2_b, w1, b1, w2, b2) = _layer_params(params, i)
        y = _ln(x, ln1_g, ln1_b)
        q = apply_rope(jnp.einsum("btd,de->bte", y, wq).reshape(b, t, h, dh), pos[None, :])
        k = apply_rope(jnp.einsum("btd,de->bte", y, wk).reshape(b, t, h, dh), pos[None, :])
        v = jnp.einsum("btd,de->bte", y, wv).reshape(b, t, h, dh)
        scores = jnp.einsum("bthd,bshd->bhts", q, k) / np.sqrt(dh)
        scores = jnp.where(causal[None, None], scores, -1e30)
        probs = jax.nn.softmax(scores, axis=-1)
        att = jnp.einsum("bhts,bshd->bthd", probs, v).reshape(b, t, h * dh)
        x = x + att @ wo
        y = _ln(x, ln2_g, ln2_b)
        x = x + jax.nn.gelu(y @ w1 + b1) @ w2 + b2

    x = _ln(x, params[-2], params[-1])
    return x @ params[0].T


# ---------------------------------------------------------------------------
# Shared single/multi-token transformer step over an external KV cache
# ---------------------------------------------------------------------------

def _attend_cache(q, k_cache, v_cache, limit):
    """q: [K?, H, Dh] vs cache [H, S, Dh]; attend rows < limit. Returns
    unnormalized flash-style (m, l, acc) so callers can merge more keys."""
    s = k_cache.shape[1]
    scores = jnp.einsum("...hd,hsd->...hs", q, k_cache)
    valid = jnp.arange(s) < limit
    scores = jnp.where(valid, scores, -1e30)
    m = scores.max(-1)
    p = jnp.exp(scores - m[..., None])
    l = p.sum(-1)
    acc = jnp.einsum("...hs,hsd->...hd", p, v_cache)
    return m, l, acc


def _merge_softmax(m1, l1, a1, m2, l2, a2):
    m = jnp.maximum(m1, m2)
    w1, w2 = jnp.exp(m1 - m), jnp.exp(m2 - m)
    return m, l1 * w1 + l2 * w2, a1 * w1[..., None] + a2 * w2[..., None]


# ---------------------------------------------------------------------------
# Prefill
# ---------------------------------------------------------------------------

def make_prefill(cfg: ModelConfig, s_pre: int):
    """(params..., tokens[s_pre], length) ->
    (logits [V], hidden [d], k_rows [L,H,s_pre,Dh], v_rows [L,H,s_pre,Dh])."""
    h, dh = cfg.n_heads, cfg.d_head

    def prefill(params, tokens, length):
        t = s_pre
        x = params[0][tokens][None]  # [1, t, d]
        pos = jnp.arange(t, dtype=jnp.int32)
        causal = jnp.tril(jnp.ones((t, t), bool))
        k_rows, v_rows = [], []
        for i in range(cfg.n_layers):
            (ln1_g, ln1_b, wq, wk, wv, wo, ln2_g, ln2_b, w1, b1, w2, b2) = _layer_params(params, i)
            y = _ln(x, ln1_g, ln1_b)
            q = apply_rope((y @ wq).reshape(1, t, h, dh), pos[None])
            k = apply_rope((y @ wk).reshape(1, t, h, dh), pos[None])
            v = (y @ wv).reshape(1, t, h, dh)
            scores = jnp.einsum("bthd,bshd->bhts", q, k) / np.sqrt(dh)
            scores = jnp.where(causal[None, None], scores, -1e30)
            att = jnp.einsum("bhts,bshd->bthd", jax.nn.softmax(scores, -1), v)
            x = x + att.reshape(1, t, h * dh) @ wo
            y = _ln(x, ln2_g, ln2_b)
            x = x + jax.nn.gelu(y @ w1 + b1) @ w2 + b2
            k_rows.append(k[0].transpose(1, 0, 2))  # [H, t, Dh]
            v_rows.append(v[0].transpose(1, 0, 2))
        x = _ln(x, params[-2], params[-1])
        hidden = x[0, length - 1]
        logits = hidden @ params[0].T
        return logits, hidden, jnp.stack(k_rows), jnp.stack(v_rows)

    return prefill


# ---------------------------------------------------------------------------
# Single-token decode
# ---------------------------------------------------------------------------

def make_decode(cfg: ModelConfig):
    """(params..., k_cache [L,H,S,Dh], v_cache, token, pos) ->
    (logits [V], hidden [d], k_row [L,H,Dh], v_row [L,H,Dh]).

    Attends to cache rows < pos plus the current token itself."""
    h, dh = cfg.n_heads, cfg.d_head

    def decode(params, k_cache, v_cache, token, pos):
        x = params[0][token]  # [d]
        pos_arr = jnp.asarray(pos, jnp.int32)[None]
        k_out, v_out = [], []
        for i in range(cfg.n_layers):
            (ln1_g, ln1_b, wq, wk, wv, wo, ln2_g, ln2_b, w1, b1, w2, b2) = _layer_params(params, i)
            y = _ln(x, ln1_g, ln1_b)
            q = apply_rope((y @ wq).reshape(1, h, dh), pos_arr)[0] / np.sqrt(dh)
            k = apply_rope((y @ wk).reshape(1, h, dh), pos_arr)[0]
            v = (y @ wv).reshape(h, dh)
            m, l, acc = _attend_cache(q, k_cache[i], v_cache[i], pos)
            # merge the token's own (k, v)
            s_self = jnp.einsum("hd,hd->h", q, k)
            m2, l2, a2 = _merge_softmax(m, l, acc, s_self, jnp.ones_like(l), v)
            att = (a2 / l2[..., None]).reshape(h * dh)
            x = x + att @ wo
            y = _ln(x, ln2_g, ln2_b)
            x = x + jax.nn.gelu(y @ w1 + b1) @ w2 + b2
            k_out.append(k)
            v_out.append(v)
        x = _ln(x, params[-2], params[-1])
        return x @ params[0].T, x, jnp.stack(k_out), jnp.stack(v_out)

    return decode


# ---------------------------------------------------------------------------
# Sampling helpers (must be mirrored exactly by rust dist::Dist)
# ---------------------------------------------------------------------------

def transform_dist(logits, temp, top_p):
    """softmax(logits / temp) followed by nucleus truncation.

    Keep order: probabilities descending, ties broken by token id ascending;
    a token is kept while the cumulative mass *before* it is < top_p.
    """
    logits = logits / jnp.maximum(temp, 1e-4)
    probs = jax.nn.softmax(logits, axis=-1)
    order = jnp.argsort(probs, axis=-1, stable=True, descending=True)
    sorted_p = jnp.take_along_axis(probs, order, axis=-1)
    cdf_excl = jnp.cumsum(sorted_p, axis=-1) - sorted_p
    keep_sorted = cdf_excl < top_p
    keep = jnp.put_along_axis(jnp.zeros_like(probs, bool), order, keep_sorted,
                              axis=-1, inplace=False)
    probs = jnp.where(keep, probs, 0.0)
    return probs / probs.sum(-1, keepdims=True)


def sample_from(probs, u):
    """Inverse-CDF sampling; probs [..., V], u [...] in [0,1)."""
    cdf = jnp.cumsum(probs, -1)
    idx = jnp.sum(cdf < u[..., None] * cdf[..., -1:], axis=-1)
    return jnp.minimum(idx, probs.shape[-1] - 1).astype(jnp.int32)


# ---------------------------------------------------------------------------
# Fused draft rollout (trunk when K == 1, branch fan-out otherwise)
# ---------------------------------------------------------------------------

def make_rollout(cfg: ModelConfig, k_paths: int, length: int):
    """(params..., k_cache, v_cache, token, pos, uniforms [K, L], temp, top_p) ->
      (tokens   [K, L]      sampled continuation per path,
       dists    [K, L, V]   transformed q at each visited node,
       hiddens  [K, L, d]   final-LN hidden at each visited node,
       k_rows   [Lyr, K, L, H, Dh], v_rows same — KV rows for visited nodes
       at positions pos..pos+L-1).

    Step j embeds the current token (the shared start token at j=0), attends
    to cache rows < pos plus its own path's rows <= j, emits the sampling
    distribution q(.|path so far) and samples the next token. All K paths run
    in one call, sharing the cache read — this is the fused drafting kernel
    that keeps python-free drafting cheap (one dispatch per stage)."""
    h, dh, d = cfg.n_heads, cfg.d_head, cfg.d_model
    kk, ll = k_paths, length

    def step_tokens(params, k_cache, v_cache, tokens_k, pos_j, own_k, own_v, j):
        """One transformer pass for the K current tokens at position pos_j.
        own_k/own_v: [Lyr, K, L, H, Dh] rows written so far (rows < j valid).
        Returns hidden [K, d] (final-LN), plus per-layer rows [Lyr, K, H, Dh]."""
        x = params[0][tokens_k]  # [K, d]
        pos_arr = jnp.broadcast_to(pos_j, (kk, 1)).astype(jnp.int32)
        rows_k, rows_v = [], []
        for i in range(cfg.n_layers):
            (ln1_g, ln1_b, wq, wk, wv, wo, ln2_g, ln2_b, w1, b1, w2, b2) = _layer_params(params, i)
            y = _ln(x, ln1_g, ln1_b)
            q = apply_rope((y @ wq).reshape(kk, 1, h, dh), pos_arr)[:, 0] / np.sqrt(dh)
            k = apply_rope((y @ wk).reshape(kk, 1, h, dh), pos_arr)[:, 0]
            v = (y @ wv).reshape(kk, h, dh)
            m, l, acc = _attend_cache(q, k_cache[i], v_cache[i], pos_j)  # [K,H]...
            # own-path rows (valid where idx < j)
            s_own = jnp.einsum("khd,klhd->khl", q, own_k[i])
            s_own = jnp.where(jnp.arange(ll)[None, None, :] < j, s_own, -1e30)
            m2 = s_own.max(-1)
            p2 = jnp.exp(s_own - m2[..., None])
            l2 = p2.sum(-1)
            a2 = jnp.einsum("khl,klhd->khd", p2, own_v[i])
            m3, l3, a3 = _merge_softmax(m, l, acc, m2, l2, a2)
            # current token's own (k, v)
            s_self = jnp.einsum("khd,khd->kh", q, k)
            m4, l4, a4 = _merge_softmax(m3, l3, a3, s_self, jnp.ones_like(l3), v)
            att = (a4 / l4[..., None]).reshape(kk, h * dh)
            x = x + att @ wo
            y = _ln(x, ln2_g, ln2_b)
            x = x + jax.nn.gelu(y @ w1 + b1) @ w2 + b2
            rows_k.append(k)
            rows_v.append(v)
        x = _ln(x, params[-2], params[-1])
        return x, jnp.stack(rows_k), jnp.stack(rows_v)

    def rollout(params, k_cache, v_cache, token, pos, uniforms, temp, top_p):
        own_k = jnp.zeros((cfg.n_layers, kk, ll, h, dh))
        own_v = jnp.zeros((cfg.n_layers, kk, ll, h, dh))
        tokens0 = jnp.broadcast_to(token, (kk,)).astype(jnp.int32)

        def body(carry, j):
            tokens_k, own_k, own_v = carry
            hidden, rk, rv = step_tokens(params, k_cache, v_cache, tokens_k,
                                         pos + j, own_k, own_v, j)
            own_k = jax.lax.dynamic_update_slice(own_k, rk[:, :, None], (0, 0, j, 0, 0))
            own_v = jax.lax.dynamic_update_slice(own_v, rv[:, :, None], (0, 0, j, 0, 0))
            logits = hidden @ params[0].T  # [K, V]
            dist = transform_dist(logits, temp, top_p)
            nxt = sample_from(dist, uniforms[:, j])
            out = (nxt, dist, hidden, rk, rv)
            return (nxt, own_k, own_v), out

        (_, _, _), (toks, dists, hiddens, rks, rvs) = jax.lax.scan(
            body, (tokens0, own_k, own_v), jnp.arange(ll))
        # scan stacks on axis 0 = step; reorder to documented layouts.
        tokens_out = toks.transpose(1, 0)                    # [K, L]
        dists_out = dists.transpose(1, 0, 2)                 # [K, L, V]
        hiddens_out = hiddens.transpose(1, 0, 2)             # [K, L, d]
        k_rows = rks.transpose(1, 2, 0, 3, 4)                # [Lyr, K, L, H, Dh]
        v_rows = rvs.transpose(1, 2, 0, 3, 4)
        return tokens_out, dists_out, hiddens_out, k_rows, v_rows

    return rollout


# ---------------------------------------------------------------------------
# Tree verification pass (target model, Pallas kernel)
# ---------------------------------------------------------------------------

def make_tree_verify(cfg: ModelConfig, n_nodes: int):
    """(params..., k_cache, v_cache, tree_tokens [N], tree_pos [N],
        tree_bias [N, N], cache_len) ->
      (logits [N, V], hidden [N, d], k_rows [Lyr, N, H, Dh], v_rows).

    One batched target pass over the whole draft tree. tree_bias[i, j] is 0
    when node j is an ancestor-or-self of node i (attention allowed) and a
    large negative number otherwise. Node 0 is by convention the root token
    (the last committed token, whose KV row is still missing); every node's
    bias row allows node 0."""
    h, dh = cfg.n_heads, cfg.d_head
    n = n_nodes

    def tree_verify(params, k_cache, v_cache, tree_tokens, tree_pos, tree_bias, cache_len):
        x = params[0][tree_tokens]  # [N, d]
        k_out, v_out = [], []
        for i in range(cfg.n_layers):
            (ln1_g, ln1_b, wq, wk, wv, wo, ln2_g, ln2_b, w1, b1, w2, b2) = _layer_params(params, i)
            y = _ln(x, ln1_g, ln1_b)
            q = apply_rope((y @ wq).reshape(n, h, dh), tree_pos)
            k = apply_rope((y @ wk).reshape(n, h, dh), tree_pos)
            v = (y @ wv).reshape(n, h, dh)
            att = tree_attention(
                q.transpose(1, 0, 2), k_cache[i], v_cache[i],
                k.transpose(1, 0, 2), v.transpose(1, 0, 2), tree_bias, cache_len)
            x = x + att.transpose(1, 0, 2).reshape(n, h * dh) @ wo
            y = _ln(x, ln2_g, ln2_b)
            x = x + jax.nn.gelu(y @ w1 + b1) @ w2 + b2
            k_out.append(k)
            v_out.append(v)
        x = _ln(x, params[-2], params[-1])
        logits = x @ params[0].T
        return logits, x, jnp.stack(k_out), jnp.stack(v_out)

    return tree_verify


# ---------------------------------------------------------------------------
# Convenience jitted wrappers for python tests
# ---------------------------------------------------------------------------

@functools.lru_cache(maxsize=None)
def jit_prefill(cfg: ModelConfig, s_pre: int):
    return jax.jit(make_prefill(cfg, s_pre))


@functools.lru_cache(maxsize=None)
def jit_decode(cfg: ModelConfig):
    return jax.jit(make_decode(cfg))


@functools.lru_cache(maxsize=None)
def jit_rollout(cfg: ModelConfig, k: int, l: int):
    return jax.jit(make_rollout(cfg, k, l))


@functools.lru_cache(maxsize=None)
def jit_tree_verify(cfg: ModelConfig, n: int):
    return jax.jit(make_tree_verify(cfg, n))
