"""Build-time training of the target/draft model pairs.

Three families mirroring the paper's capability ratios (DESIGN.md §5). Each
model is a byte-level GPT trained with hand-rolled AdamW (no optax in this
environment) on the synthetic multi-domain corpus. Training runs once per
`make artifacts`; weights are cached under artifacts/<family>/.
"""

from __future__ import annotations

import functools
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from . import corpus as corpus_mod
from .model import ModelConfig, init_params, train_forward

# ---------------------------------------------------------------------------
# Families — DESIGN.md §5. d_head = 32 everywhere so the Pallas kernel sees a
# single head geometry across families.
# ---------------------------------------------------------------------------

FAMILIES: dict[str, dict] = {
    # medium-weak draft (Qwen-2.5 32B/0.5B analogue)
    "qwen-sim": {
        "target": ModelConfig(n_layers=4, d_model=128, n_heads=4, d_head=32),
        "draft": ModelConfig(n_layers=2, d_model=64, n_heads=2, d_head=32),
        "draft_step_frac": 1.0,
    },
    # very weak draft (Gemma-3 27B/270M analogue): tiny and under-trained
    "gemma-sim": {
        "target": ModelConfig(n_layers=5, d_model=128, n_heads=4, d_head=32),
        "draft": ModelConfig(n_layers=1, d_model=32, n_heads=1, d_head=32),
        "draft_step_frac": 0.34,
    },
    # strong draft (Llama-3 70B/8B analogue)
    "llama-sim": {
        "target": ModelConfig(n_layers=4, d_model=128, n_heads=4, d_head=32),
        "draft": ModelConfig(n_layers=3, d_model=96, n_heads=3, d_head=32),
        "draft_step_frac": 1.0,
    },
}

BATCH = 4
SEQ = 64


def default_steps() -> int:
    return int(os.environ.get("SPECDELAY_TRAIN_STEPS", "300"))


# ---------------------------------------------------------------------------
# AdamW
# ---------------------------------------------------------------------------

def adamw_update(params, grads, m, v, step, lr, wd=0.01, b1=0.9, b2=0.999, eps=1e-8):
    new_p, new_m, new_v = [], [], []
    t = step + 1
    c1 = 1.0 - b1 ** t
    c2 = 1.0 - b2 ** t
    for p, g, mi, vi in zip(params, grads, m, v):
        mi = b1 * mi + (1 - b1) * g
        vi = b2 * vi + (1 - b2) * g * g
        upd = (mi / c1) / (jnp.sqrt(vi / c2) + eps)
        decay = wd if p.ndim >= 2 else 0.0  # no decay on gains/biases
        new_p.append(p - lr * (upd + decay * p))
        new_m.append(mi)
        new_v.append(vi)
    return new_p, new_m, new_v


def lr_schedule(step, steps, peak=3e-3, warmup=20):
    warm = peak * (step + 1) / warmup
    t = jnp.clip((step - warmup) / jnp.maximum(steps - warmup, 1), 0.0, 1.0)
    cos = peak * (0.1 + 0.9 * 0.5 * (1 + jnp.cos(jnp.pi * t)))
    return jnp.where(step < warmup, warm, cos)


def _loss_fn(cfg, params, x, y):
    logits = train_forward(cfg, params, x)
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, y[..., None], axis=-1)[..., 0]
    return nll.mean()


@functools.lru_cache(maxsize=None)
def _train_step(cfg: ModelConfig, total_steps: int):
    def step_fn(params, m, v, x, y, step):
        loss, grads = jax.value_and_grad(lambda p: _loss_fn(cfg, p, x, y))(params)
        gnorm = jnp.sqrt(sum(jnp.sum(g * g) for g in grads))
        clip = jnp.minimum(1.0, 1.0 / (gnorm + 1e-6))
        grads = [g * clip for g in grads]
        lr = lr_schedule(step, total_steps)
        params, m, v = adamw_update(params, grads, m, v, step, lr)
        return params, m, v, loss

    return jax.jit(step_fn, donate_argnums=(0, 1, 2))


def train_model(cfg: ModelConfig, data: np.ndarray, steps: int, seed: int,
                log_prefix: str = "") -> tuple[list, float]:
    """Train one model on a uint8 token stream; returns (params, eval loss)."""
    params = init_params(cfg, seed)
    m = [jnp.zeros_like(p) for p in params]
    v = [jnp.zeros_like(p) for p in params]
    step_fn = _train_step(cfg, steps)
    rng = np.random.default_rng(seed + 1)
    n = len(data) - SEQ - 1

    t0 = time.time()
    loss = None
    for s in range(steps):
        idx = rng.integers(0, n, BATCH)
        x = np.stack([data[i:i + SEQ] for i in idx]).astype(np.int32)
        y = np.stack([data[i + 1:i + 1 + SEQ] for i in idx]).astype(np.int32)
        params, m, v, loss = step_fn(params, m, v, jnp.array(x), jnp.array(y), s)
        if s % 50 == 0 or s == steps - 1:
            print(f"  {log_prefix} step {s:4d} loss {float(loss):.4f} "
                  f"({time.time() - t0:.0f}s)", flush=True)

    # held-out eval
    eval_rng = np.random.default_rng(987)
    idx = eval_rng.integers(0, n, 16)
    x = np.stack([data[i:i + SEQ] for i in idx]).astype(np.int32)
    y = np.stack([data[i + 1:i + 1 + SEQ] for i in idx]).astype(np.int32)
    eval_loss = float(_loss_fn(cfg, params, jnp.array(x), jnp.array(y)))
    return params, eval_loss


def train_family(name: str, steps: int | None = None, seed: int = 7):
    """Train the (target, draft) pair for one family."""
    spec = FAMILIES[name]
    steps = steps or default_steps()
    data = np.frombuffer(corpus_mod.build_corpus(seed=0), dtype=np.uint8)
    print(f"[train] family={name} corpus={len(data)} bytes steps={steps}")
    target, t_loss = train_model(spec["target"], data, steps, seed,
                                 log_prefix=f"{name}/target")
    d_steps = max(20, int(steps * spec["draft_step_frac"]))
    draft, d_loss = train_model(spec["draft"], data, d_steps, seed + 100,
                                log_prefix=f"{name}/draft")
    print(f"[train] {name}: target eval {t_loss:.4f}, draft eval {d_loss:.4f}")
    return target, draft, t_loss, d_loss
