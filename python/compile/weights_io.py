"""Flat binary tensor container shared between python (writer) and rust (reader).

Format (little-endian):
    magic   u32 = 0x53504457  ("SPDW")
    version u32 = 1
    count   u32
    then per tensor:
        name_len u32, name bytes (utf-8)
        ndim     u32, dims u32 * ndim
        data     f32 * prod(dims)

Tensors are written in the exact order the AOT-lowered HLO entry expects its
parameter buffers, so the rust loader can upload them positionally.
"""

from __future__ import annotations

import struct

import numpy as np

MAGIC = 0x53504457
VERSION = 1


def write_tensors(path: str, tensors: list[tuple[str, np.ndarray]]) -> None:
    with open(path, "wb") as f:
        f.write(struct.pack("<III", MAGIC, VERSION, len(tensors)))
        for name, arr in tensors:
            arr = np.ascontiguousarray(arr, dtype=np.float32)
            nb = name.encode("utf-8")
            f.write(struct.pack("<I", len(nb)))
            f.write(nb)
            f.write(struct.pack("<I", arr.ndim))
            f.write(struct.pack(f"<{arr.ndim}I", *arr.shape))
            f.write(arr.tobytes())


def read_tensors(path: str) -> list[tuple[str, np.ndarray]]:
    out = []
    with open(path, "rb") as f:
        magic, version, count = struct.unpack("<III", f.read(12))
        assert magic == MAGIC, f"bad magic {magic:#x}"
        assert version == VERSION
        for _ in range(count):
            (name_len,) = struct.unpack("<I", f.read(4))
            name = f.read(name_len).decode("utf-8")
            (ndim,) = struct.unpack("<I", f.read(4))
            dims = struct.unpack(f"<{ndim}I", f.read(4 * ndim)) if ndim else ()
            n = int(np.prod(dims)) if ndim else 1
            data = np.frombuffer(f.read(4 * n), dtype=np.float32).reshape(dims)
            out.append((name, data))
    return out
