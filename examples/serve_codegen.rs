//! Code-generation serving scenario: the coding workload the paper's intro
//! motivates. Compares Traversal vs SpecInfer-with-delayed-expansion on
//! code prompts and reports latency. Runs on the CPU reference backend —
//! no artifacts needed.
use specdelay::coordinator::{FixedPolicy, SpecEngine};
use specdelay::dist::SamplingConfig;
use specdelay::draft::Action;
use specdelay::runtime::{CpuModelConfig, CpuRefBackend};
use specdelay::util::Pcg64;
use specdelay::verify;

fn main() -> anyhow::Result<()> {
    let backend = CpuRefBackend::new(&CpuModelConfig::small(), 11);
    let spec = SpecEngine::new(&backend, SamplingConfig::new(0.2, 1.0));
    let prompts = [
        "def fib(n):\n    ",
        "fn main() { println!(",
        "SELECT name FROM users WHERE ",
    ];
    for name in ["Traversal", "SpecInfer"] {
        let verifier = verify::verifier(name).unwrap();
        let action = if name == "Traversal" { Action::new(4, 0, 4) } else { Action::new(3, 2, 3) };
        let mut rng = Pcg64::seeded(7);
        let mut total_toks = 0usize;
        let mut total_secs = 0.0f64;
        for p in &prompts {
            let (text, stats) =
                spec.generate(p, 48, verifier.as_ref(), &FixedPolicy(action), &mut rng)?;
            println!("[{name}] {:?}\n  -> {:?}", p.trim_end(), text);
            total_toks += stats.tokens;
            total_secs += stats.wall_secs;
        }
        println!(
            "[{name}] served {} requests: {} tokens in {total_secs:.2}s = {:.1} tok/s\n",
            prompts.len(),
            total_toks,
            total_toks as f64 / total_secs
        );
    }
    Ok(())
}
