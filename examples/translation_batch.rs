//! Translation scenario: a batch of en->fr/es prompts across three seeded
//! CPU reference model pairs (standing in for the paper's three families),
//! comparing every verification algorithm's block efficiency.
use specdelay::benchkit::print_table;
use specdelay::coordinator::{FixedPolicy, SpecEngine};
use specdelay::dist::SamplingConfig;
use specdelay::draft::Action;
use specdelay::runtime::{CpuModelConfig, CpuRefBackend};
use specdelay::util::Pcg64;
use specdelay::verify;

fn main() -> anyhow::Result<()> {
    let prompts = [
        "translate en->fr: the sea is calm => ",
        "translate en->es: good morning, friend => ",
    ];
    let backends: Vec<CpuRefBackend> = (0..3u64)
        .map(|seed| CpuRefBackend::new(&CpuModelConfig::small(), seed))
        .collect();
    let algos = ["Naive", "BV", "NSS", "NaiveTree", "SpecTr", "SpecInfer", "Khisti", "Traversal"];
    let mut rows = Vec::new();
    for algo in algos {
        let mut cols = Vec::new();
        for backend in &backends {
            let spec = SpecEngine::new(backend, SamplingConfig::new(0.8, 1.0));
            let verifier = verify::verifier(algo).unwrap();
            let action = if algo == "Naive" || algo == "BV" {
                Action::new(1, 5, 0)
            } else {
                Action::new(3, 0, 4)
            };
            let mut rng = Pcg64::seeded(3);
            let mut be = 0.0;
            for p in &prompts {
                let (_t, stats) =
                    spec.generate(p, 32, verifier.as_ref(), &FixedPolicy(action), &mut rng)?;
                be += stats.block_efficiency() / prompts.len() as f64;
            }
            cols.push(be);
        }
        rows.push((algo.to_string(), cols));
    }
    print_table(
        "translation block efficiency by model seed (cpu-ref)",
        &["seed0", "seed1", "seed2"],
        &rows,
    );
    Ok(())
}
