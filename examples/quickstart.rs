//! Quickstart: build the hermetic CPU reference backend, run delayed-
//! expansion speculative decoding, print the continuation and stats.
//!
//! Runs out of the box — no artifacts, no PJRT:
//!
//!     cargo run --release --example quickstart
use specdelay::coordinator::{FixedPolicy, SpecEngine};
use specdelay::dist::SamplingConfig;
use specdelay::draft::Action;
use specdelay::runtime::{CpuModelConfig, CpuRefBackend};
use specdelay::util::Pcg64;
use specdelay::verify;

fn main() -> anyhow::Result<()> {
    let backend = CpuRefBackend::new(&CpuModelConfig::small(), 0);
    let spec = SpecEngine::new(&backend, SamplingConfig::new(0.6, 1.0));
    let verifier = verify::verifier("SpecInfer").unwrap();
    // delayed tree: trunk of 2, then 3 branches of 3 (paper Definition 5.2)
    let policy = FixedPolicy(Action::new(3, 2, 3));
    let mut rng = Pcg64::seeded(0);
    for prompt in ["Q: 6 * 7 = ? A:", "story: the golden ", "translate en->fr: the sea => "] {
        let (text, stats) = spec.generate(prompt, 48, verifier.as_ref(), &policy, &mut rng)?;
        println!("prompt:  {prompt:?}");
        println!("output:  {:?}", text);
        println!(
            "         {} tokens | block efficiency {:.2} | {:.1} tok/s\n",
            stats.tokens,
            stats.block_efficiency(),
            stats.tps()
        );
    }
    Ok(())
}
