//! End-to-end serving driver (DESIGN.md validation requirement): starts the
//! TCP server on a real model family, fires a batch of mixed-domain
//! requests through the line protocol, and reports per-request latency and
//! aggregate throughput.
use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::thread;
use std::time::{Duration, Instant};

use specdelay::benchkit::{load_engine, load_prompts, DOMAINS};
use specdelay::coordinator::server::{serve, ServerConfig};
use specdelay::util::stats::Running;
use specdelay::util::Json;

fn main() -> anyhow::Result<()> {
    let addr = "127.0.0.1:7411";
    let n_requests = 6usize;

    // leader: spawn the server thread
    let server_handle = thread::spawn(move || {
        let engine = load_engine("qwen-sim").expect("engine");
        let cfg = ServerConfig { addr: addr.to_string(), seed: 42 };
        serve(&engine, &cfg, Some(n_requests)).expect("serve");
    });
    thread::sleep(Duration::from_secs(3)); // engine load

    // client: mixed-domain batch
    let mut reqs = Vec::new();
    for (i, domain) in DOMAINS.iter().cycle().take(n_requests).enumerate() {
        let p = load_prompts(domain, i / DOMAINS.len() + 1)?.pop().unwrap();
        reqs.push((domain.to_string(), p));
    }

    let mut stream = loop {
        match TcpStream::connect(addr) {
            Ok(s) => break s,
            Err(_) => thread::sleep(Duration::from_millis(200)),
        }
    };
    let mut reader = BufReader::new(stream.try_clone()?);
    let mut latency = Running::new();
    let mut total_tokens = 0.0;
    let t0 = Instant::now();
    for (domain, prompt) in &reqs {
        let req = format!(
            "{{\"prompt\": {}, \"max_new\": 32, \"temperature\": 0.8, \"verifier\": \"SpecInfer\", \"k\": 3, \"l1\": 2, \"l2\": 3}}",
            Json::Str(prompt.clone())
        );
        let t1 = Instant::now();
        writeln!(stream, "{req}")?;
        let mut line = String::new();
        reader.read_line(&mut line)?;
        let dt = t1.elapsed().as_secs_f64();
        latency.push(dt);
        let resp = Json::parse(line.trim()).map_err(|e| anyhow::anyhow!("{e}"))?;
        let tokens = resp.get("tokens").map_err(|e| anyhow::anyhow!("{e}"))?.as_f64().unwrap_or(0.0);
        let be = resp.get("block_efficiency").map_err(|e| anyhow::anyhow!("{e}"))?.as_f64().unwrap_or(0.0);
        total_tokens += tokens;
        println!("[{domain:<12}] {tokens:>3.0} tokens in {dt:.2}s (block eff {be:.2})");
    }
    let wall = t0.elapsed().as_secs_f64();
    drop(stream);
    server_handle.join().ok();
    println!(
        "\nserved {} requests | mean latency {:.2}s (min {:.2} max {:.2}) | aggregate {:.1} tok/s",
        reqs.len(),
        latency.mean(),
        latency.min(),
        latency.max(),
        total_tokens / wall
    );
    Ok(())
}
