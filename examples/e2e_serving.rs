//! End-to-end serving driver: starts the TCP server on the CPU reference
//! backend, fires a batch of mixed-domain requests through the line
//! protocol, and reports per-request latency and aggregate throughput.
//! Hermetic — no artifacts, no PJRT.
use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::thread;
use std::time::{Duration, Instant};

use specdelay::coordinator::server::{serve, ServerConfig};
use specdelay::runtime::{CpuModelConfig, CpuRefBackend};
use specdelay::util::stats::Running;
use specdelay::util::Json;

fn main() -> anyhow::Result<()> {
    let addr = "127.0.0.1:7411";
    let n_requests = 6usize;

    // leader: spawn the server thread
    let server_handle = thread::spawn(move || {
        let backend = CpuRefBackend::new(&CpuModelConfig::small(), 42);
        let cfg = ServerConfig { addr: addr.to_string(), seed: 42 };
        serve(&backend, &cfg, Some(n_requests)).expect("serve");
    });

    // client: mixed-domain batch
    let reqs: Vec<(&str, &str)> = vec![
        ("writing", "story: the golden "),
        ("coding", "def fib(n):\n    "),
        ("translation", "translate en->fr: the sea => "),
        ("math_easy", "Q: 6 * 7 = ? A:"),
        ("math_hard", "Q: integrate x^2 from 0 to 3. A:"),
        ("writing", "essay: on the value of "),
    ];

    let mut stream = loop {
        match TcpStream::connect(addr) {
            Ok(s) => break s,
            Err(_) => thread::sleep(Duration::from_millis(100)),
        }
    };
    let mut reader = BufReader::new(stream.try_clone()?);
    let mut latency = Running::new();
    let mut total_tokens = 0.0;
    let t0 = Instant::now();
    for (domain, prompt) in &reqs {
        let req = format!(
            "{{\"prompt\": {}, \"max_new\": 32, \"temperature\": 0.8, \"verifier\": \"SpecInfer\", \"k\": 3, \"l1\": 2, \"l2\": 3}}",
            Json::Str(prompt.to_string())
        );
        let t1 = Instant::now();
        writeln!(stream, "{req}")?;
        let mut line = String::new();
        reader.read_line(&mut line)?;
        let dt = t1.elapsed().as_secs_f64();
        latency.push(dt);
        let resp = Json::parse(line.trim()).map_err(|e| anyhow::anyhow!("{e}"))?;
        let tokens = resp.get("tokens").map_err(|e| anyhow::anyhow!("{e}"))?.as_f64().unwrap_or(0.0);
        let be = resp
            .get("block_efficiency")
            .map_err(|e| anyhow::anyhow!("{e}"))?
            .as_f64()
            .unwrap_or(0.0);
        total_tokens += tokens;
        println!("[{domain:<12}] {tokens:>3.0} tokens in {dt:.2}s (block eff {be:.2})");
    }
    let wall = t0.elapsed().as_secs_f64();
    drop(stream);
    server_handle.join().ok();
    println!(
        "\nserved {} requests | mean latency {:.2}s (min {:.2} max {:.2}) | aggregate {:.1} tok/s",
        reqs.len(),
        latency.mean(),
        latency.min(),
        latency.max(),
        total_tokens / wall
    );
    Ok(())
}
